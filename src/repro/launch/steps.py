"""Per-(architecture x shape) step functions + ShapeDtypeStruct input specs.

This is the single source of truth the dry-run, trainer and server share:
for every cell it provides

    build_cell(arch, shape_name, ctx) -> Cell(fn, args_sds, in_shardings)

where ``fn`` is the jittable step (train_step / prefill / decode / serve /
retrieval), ``args_sds`` are weak-type-correct ShapeDtypeStruct stand-ins
(no device allocation — the FULL configs are only ever lowered), and
``in_shardings`` mirror ``args_sds`` with NamedShardings derived from the
arch's logical rules.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import configs as config_registry
from repro.configs.base import (GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES,
                                GNNShape, LMShape, RecSysShape,
                                RecSysConfig, SchNetConfig, TransformerConfig)
from repro.distributed.sharding import ParallelCtx, params_sharding
from repro.models import recsys as R
from repro.models import schnet as S
from repro.models import transformer as T
from repro.optim import make_optimizer


class Cell(NamedTuple):
    fn: Callable
    args: tuple                 # ShapeDtypeStructs (or pytrees thereof)
    in_shardings: tuple
    cfg: Any
    shape: Any
    donate: tuple = ()


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def shape_by_name(family: str, name: str):
    table = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[family]
    return {s.name: s for s in table}[name]


def abstract_init(init_fn, key, cfg):
    """Trace an ``init(key, cfg) -> (params, axes)`` function abstractly:
    params come back as ShapeDtypeStructs (NO allocation — full configs are
    hundreds of GB), the static axes tree is captured via closure."""
    box = {}

    def wrapper(k):
        p, a = init_fn(k, cfg)
        box["axes"] = a
        return p

    sds = jax.eval_shape(wrapper, key)
    return sds, box["axes"]


# ---------------------------------------------------------------------------
# Rules specialisation per shape.
# ---------------------------------------------------------------------------

def _fit_batch_rule(rules: dict, mesh, global_batch: int) -> None:
    """Trim the batch rule's mesh axes until the batch divides the DP
    degree (e.g. pure-DP smollm: batch 256 can't split 512 ways on the
    multi-pod mesh -> drop the leading axis)."""
    from repro.distributed.mesh_utils import mesh_axis_size

    axes = rules.get("batch")
    if axes is None or mesh is None:
        return
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    while axes and global_batch % mesh_axis_size(mesh, axes) != 0:
        axes = axes[1:]
    rules["batch"] = axes if axes else None


def rules_for_shape(cfg, shape, mesh=None) -> dict:
    rules = dict(cfg.rules)
    if isinstance(shape, LMShape):
        if shape.kind == "decode":
            # §Perf findings (EXPERIMENTS.md):
            #  (1) heads-sharded activations force GSPMD to all-gather the
            #      seq-sharded KV cache every step (18-70 GiB/step!);
            #  (2) naive fix (replicate attention weights) re-bloats params
            #      by GBs.  Final plan: shard WEIGHTS on the d ("embed")
            #      axis — per-token activations are KBs, so the psums this
            #      induces are noise, while params stay 16-way sharded and
            #      the cache streams from its seq-sharded home.
            rules["heads"] = None
            rules["embed"] = "model"
            rules["ff"] = None
            rules["vocab"] = None
            rules["seq_act"] = None
            if shape.global_batch == 1:
                # long-context single sequence: nothing to DP over — shard
                # the KV cache sequence dim over BOTH axes (DESIGN.md §6).
                rules["batch"] = None
                rules["kv_seq"] = ("data", "model")
            else:
                rules["kv_seq"] = "model"
            # batch must not reuse axes claimed by the cache seq dim
            # (pure-DP archs map batch over (data, model))
            b = rules.get("batch")
            if b is not None:
                kv = rules["kv_seq"]
                kv_axes = {kv} if isinstance(kv, str) else set(kv)
                axes = (b,) if isinstance(b, str) else tuple(b)
                axes = tuple(a for a in axes if a not in kv_axes)
                rules["batch"] = axes or None
    if isinstance(shape, RecSysShape) and shape.kind == "retrieval":
        rules["batch"] = None                       # B=1
    if isinstance(shape, LMShape):
        if shape.kind == "prefill":
            # prefill batches are small (32): batch can't absorb the model
            # axis — keep batch on DP axes and hand the model axis to the
            # sequence dim (pure-DP archs would otherwise replicate 16x).
            b = rules.get("batch")
            if b is not None:
                axes = (b,) if isinstance(b, str) else tuple(b)
                rules["batch"] = tuple(a for a in axes if a != "model") or None
            if rules.get("seq_act") is None:
                rules["seq_act"] = "model"
        _fit_batch_rule(rules, mesh, shape.global_batch)
    return rules


# ---------------------------------------------------------------------------
# Optimizer state specs.
# ---------------------------------------------------------------------------

def _opt_axes_safe(optimizer_name, params_sds, params_axes):
    from repro.optim.optimizer import AdamState, AdafactorState

    if optimizer_name == "adamw":
        return AdamState(step=(), m=params_axes, v=params_axes)
    # adafactor: walk the two trees explicitly (tuple-leaf trees confuse
    # tree_map is_leaf when nesting tuples), building vr/vc axes trees.
    flat_sds, treedef = jax.tree_util.tree_flatten(params_sds)
    flat_axes = treedef.flatten_up_to(params_axes)
    vr_flat, vc_flat = [], []
    for sds, axes in zip(flat_sds, flat_axes):
        axes = tuple(axes)
        if len(sds.shape) >= 2:
            vr_flat.append(axes[:-1])
            vc_flat.append(axes[:-2] + (axes[-1],))
        else:
            vr_flat.append(axes)
            vc_flat.append((None,))
    return AdafactorState(
        step=(),
        vr=jax.tree_util.tree_unflatten(treedef, vr_flat),
        vc=jax.tree_util.tree_unflatten(treedef, vc_flat),
    )


# ---------------------------------------------------------------------------
# LM cells.
# ---------------------------------------------------------------------------

def zero_axes_of(params_sds, params_axes, ctx: ParallelCtx,
                 zero_axis: str = "data"):
    """ZeRO-1 sharding plan: for each leaf, additionally shard the first
    unsharded, 16-divisible dim over ``zero_axis``.  Leaves that already
    consume the data axis (arctic's EP-over-data experts) or have no
    eligible dim keep their original axes.  Verified constructible by
    building the NamedSharding (fall back on DuplicateSpecError)."""
    flat_sds, treedef = jax.tree_util.tree_flatten(params_sds)
    flat_axes = treedef.flatten_up_to(params_axes)
    out = []
    for sds, axes in zip(flat_sds, flat_axes):
        axes = tuple(axes)
        cand = None
        for i, (dim, ax) in enumerate(zip(sds.shape, axes)):
            if ax is None and dim % 16 == 0:
                cand = axes[:i] + (zero_axis,) + axes[i + 1:]
                break
        if cand is not None and ctx.mesh is not None:
            try:
                ctx.sharding(*cand)
            except Exception:  # noqa: BLE001 — duplicate mesh axis etc.
                cand = None
        out.append(cand if cand is not None else axes)
    return jax.tree_util.tree_unflatten(treedef, out)


def make_lm_train_step(cfg: TransformerConfig, ctx: ParallelCtx,
                       lr: float = 1e-4, params_axes=None, params_sds=None):
    opt = make_optimizer(cfg.optimizer)

    zero_shardings = None
    if cfg.zero_sharding and params_axes is not None and ctx.mesh is not None:
        zaxes = zero_axes_of(params_sds, params_axes, ctx)
        zero_shardings = params_sharding(zaxes, ctx)

    def zconstrain(tree):
        if zero_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s)
            if s is not None else x, tree, zero_shardings)

    def step(params, opt_state, batch):
        k = max(1, cfg.grad_accum)
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                T.lm_loss, has_aux=True)(params, batch, cfg, ctx)
            grads = zconstrain(grads)
        else:
            # microbatched gradient accumulation: activations live for ONE
            # microbatch at a time; the accumulator (and, with ZeRO, the
            # optimizer path) is sharded over the data axis so only one
            # full-size gradient is ever live (EXPERIMENTS.md §Perf).
            def split(x):
                return x.reshape(k, x.shape[0] // k, *x.shape[1:])

            ub = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    T.lm_loss, has_aux=True)(params, mb, cfg, ctx)
                g_acc = zconstrain(jax.tree.map(jnp.add, g_acc, zconstrain(g)))
                return (g_acc, l_acc + l), None

            zeros = zconstrain(jax.tree.map(jnp.zeros_like, params))
            (g_sum, l_sum), _ = jax.lax.scan(body, (zeros, 0.0), ub)
            grads = jax.tree.map(lambda g: g / k, g_sum)
            loss = l_sum / k
            metrics = {}
        # ZeRO-1: update computed in the zero-sharded layout (grads + opt
        # state live there); the new params are re-gathered to their
        # compute sharding by the in/out sharding contract.
        new_params, new_state = opt.step(grads, opt_state,
                                         zconstrain(params), lr)
        return new_params, new_state, {"loss": loss, **metrics}

    return step, opt


def _lm_cell(cfg: TransformerConfig, shape: LMShape, ctx: ParallelCtx) -> Cell:
    key = jax.random.PRNGKey(0)
    params_sds, params_axes = abstract_init(T.init_transformer, key, cfg)
    p_shard = params_sharding(params_axes, ctx)
    b = shape.global_batch
    s = shape.seq_len

    if shape.kind == "train":
        # CE-chunk scan unrolled (8 trips) so loss FLOPs are counted exactly;
        # the layer scan stays rolled — the block PROBE corrects it.
        cfg = dataclasses.replace(cfg, ce_unroll=True)
        step, opt = make_lm_train_step(cfg, ctx, params_axes=params_axes,
                                       params_sds=params_sds)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        state_axes = (zero_axes_of(params_sds, params_axes, ctx)
                      if (cfg.zero_sharding and ctx.mesh is not None)
                      else params_axes)
        opt_axes = _opt_axes_safe(cfg.optimizer, params_sds, state_axes)
        o_shard = params_sharding(opt_axes, ctx)
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        b_shard = {
            "tokens": ctx.sharding("batch", None),
            "targets": ctx.sharding("batch", None),
        }
        return Cell(step, (params_sds, opt_sds, batch_sds),
                    (p_shard, o_shard, b_shard), cfg, shape, donate=(0, 1))

    if shape.kind == "prefill":
        fn = functools.partial(T.prefill_step, cfg=cfg, ctx=ctx)
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return Cell(lambda p, t: fn(p, t), (params_sds, toks),
                    (p_shard, ctx.sharding("batch", None)), cfg, shape)

    # decode: one new token against a seq_len KV cache
    dcfg = dataclasses.replace(cfg, attn_chunk_q=1, attn_chunk_kv=s)
    cache_sds = jax.eval_shape(lambda: T.init_cache(dcfg, b, s))
    cache_axes = T.cache_axes(dcfg)
    c_shard = jax.tree.map(
        lambda ax: ctx.sharding(*ax), cache_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x))
    toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def step(params, cache, tokens):
        return T.decode_step(params, cache, tokens, s - 1, dcfg, ctx)

    return Cell(step, (params_sds, cache_sds, toks),
                (p_shard, c_shard, ctx.sharding("batch", None)),
                dcfg, shape, donate=(1,))


# ---------------------------------------------------------------------------
# GNN cells.
# ---------------------------------------------------------------------------

def make_gnn_train_step(cfg: SchNetConfig, ctx: ParallelCtx, lr: float = 1e-3,
                        n_graphs: int = 0):
    opt = make_optimizer("adamw")

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: S.schnet_loss(p, batch, cfg, ctx, n_graphs),
            has_aux=True)(params)
        new_params, new_state = opt.step(grads, opt_state, params, lr)
        return new_params, new_state, {"loss": loss, **metrics}

    return step, opt


def _gnn_batch_sds(cfg: SchNetConfig, shape: GNNShape, edge_multiple: int):
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind == "batched":
        n = shape.n_nodes * shape.batch_graphs
        e = _round_up(shape.n_edges * shape.batch_graphs, edge_multiple)
        return S.GraphBatch(
            node_z=jax.ShapeDtypeStruct((n,), i32),
            senders=jax.ShapeDtypeStruct((e,), i32),
            receivers=jax.ShapeDtypeStruct((e,), i32),
            distances=jax.ShapeDtypeStruct((e,), f32),
            edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
            graph_ids=jax.ShapeDtypeStruct((n,), i32),
            targets=jax.ShapeDtypeStruct((shape.batch_graphs,), f32),
        )
    if shape.kind == "sampled":
        seeds = shape.batch_nodes
        f1, f2 = shape.fanout
        n = _round_up(seeds * (1 + f1 + f1 * f2), 1024)
        e = _round_up(seeds * f1 + seeds * f1 * f2, edge_multiple)
        return S.GraphBatch(
            node_z=jax.ShapeDtypeStruct((n,), i32),
            senders=jax.ShapeDtypeStruct((e,), i32),
            receivers=jax.ShapeDtypeStruct((e,), i32),
            distances=jax.ShapeDtypeStruct((e,), f32),
            edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
            targets=jax.ShapeDtypeStruct((n,), f32),
        )
    # full graph
    n = shape.n_nodes
    e = _round_up(shape.n_edges, edge_multiple)
    return S.GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n, shape.d_feat), f32),
        senders=jax.ShapeDtypeStruct((e,), i32),
        receivers=jax.ShapeDtypeStruct((e,), i32),
        distances=jax.ShapeDtypeStruct((e,), f32),
        edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
        targets=jax.ShapeDtypeStruct((n,), f32),
    )


def _gnn_cell(cfg: SchNetConfig, shape: GNNShape, ctx: ParallelCtx) -> Cell:
    # dry-run exactness: unroll the (3-deep) interaction scan so
    # cost_analysis counts every trip (DESIGN.md §7).
    cfg = dataclasses.replace(cfg, unroll=True)
    if shape.kind == "sampled":
        cfg = dataclasses.replace(cfg, max_z=shape.n_nodes)
    params_sds, params_axes = abstract_init(S.init_schnet,
                                            jax.random.PRNGKey(0), cfg)
    p_shard = params_sharding(params_axes, ctx)

    edge_mult = 2048
    batch = _gnn_batch_sds(cfg, shape, edge_mult)
    e_shard = ctx.sharding("edges")
    n_shard = ctx.sharding("nodes")
    b_shard = S.GraphBatch(
        node_z=n_shard if batch.node_z is not None else None,
        node_feat=(ctx.sharding("nodes", None)
                   if batch.node_feat is not None else None),
        senders=e_shard, receivers=e_shard, distances=e_shard,
        edge_mask=e_shard if batch.edge_mask is not None else None,
        graph_ids=n_shard if batch.graph_ids is not None else None,
        targets=n_shard,
    )
    step, opt = make_gnn_train_step(
        cfg, ctx, n_graphs=(shape.batch_graphs if shape.kind == "batched" else 0))
    opt_sds = jax.eval_shape(opt.init, params_sds)
    opt_axes = _opt_axes_safe("adamw", params_sds, params_axes)
    o_shard = params_sharding(opt_axes, ctx)
    return Cell(step, (params_sds, opt_sds, batch),
                (p_shard, o_shard, b_shard), cfg, shape, donate=(0, 1))


# ---------------------------------------------------------------------------
# RecSys cells.
# ---------------------------------------------------------------------------

def make_recsys_train_step(cfg: RecSysConfig, ctx: ParallelCtx, lr: float = 1e-3):
    opt = make_optimizer("adamw")

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: R.bce_loss(p, cfg, batch, ctx), has_aux=True)(params)
        new_params, new_state = opt.step(grads, opt_state, params, lr)
        return new_params, new_state, {"loss": loss, **metrics}

    return step, opt


def _recsys_batch_sds(cfg: RecSysConfig, shape: RecSysShape):
    i32, f32 = jnp.int32, jnp.float32
    b = shape.batch
    fields = {}
    for f in cfg.fields:
        fields[f.name] = (jax.ShapeDtypeStruct((b, f.multi_hot), i32)
                          if f.multi_hot > 1 else jax.ShapeDtypeStruct((b,), i32))
    hist = (jax.ShapeDtypeStruct((b, cfg.seq_len), i32)
            if cfg.seq_len else None)
    tgt = jax.ShapeDtypeStruct((b,), i32) if cfg.item_vocab else None
    label = jax.ShapeDtypeStruct((b,), f32)
    # candidate axis shards over (data x model) = 256; pad to a multiple
    # (padding ids repeat id 0; scores for them are real but never change
    # the top-k unless k ~ n_candidates).
    cand = (jax.ShapeDtypeStruct((b, _round_up(shape.n_candidates, 2048)), i32)
            if shape.kind == "retrieval" else None)
    return R.RecBatch(fields=fields, history=hist, target_item=tgt,
                      label=label, candidates=cand)


def _recsys_cell(cfg: RecSysConfig, shape: RecSysShape, ctx: ParallelCtx) -> Cell:
    cfg = dataclasses.replace(cfg, unroll=True)   # exact GRU-scan accounting
    params_sds, params_axes = abstract_init(R.init_recsys,
                                            jax.random.PRNGKey(0), cfg)
    p_shard = params_sharding(params_axes, ctx)
    batch = _recsys_batch_sds(cfg, shape)
    bs = ctx.sharding("batch")
    bs2 = ctx.sharding("batch", None)
    b_shard = R.RecBatch(
        fields={k: (bs2 if v.ndim == 2 else bs) for k, v in batch.fields.items()},
        history=bs2 if batch.history is not None else None,
        target_item=bs if batch.target_item is not None else None,
        label=bs,
        candidates=(ctx.sharding("batch", "candidates")
                    if batch.candidates is not None else None),
    )

    if shape.kind == "train":
        step, opt = make_recsys_train_step(cfg, ctx)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_axes = _opt_axes_safe("adamw", params_sds, params_axes)
        o_shard = params_sharding(opt_axes, ctx)
        return Cell(step, (params_sds, opt_sds, batch),
                    (p_shard, o_shard, b_shard), cfg, shape, donate=(0, 1))
    if shape.kind == "serve":
        fn = lambda p, bt: R.forward_logits(p, cfg, bt, ctx)
        return Cell(fn, (params_sds, batch), (p_shard, b_shard), cfg, shape)
    # retrieval
    fn = lambda p, bt: R.retrieval_scores(p, cfg, bt, ctx, k=100)
    return Cell(fn, (params_sds, batch), (p_shard, b_shard), cfg, shape)


# ---------------------------------------------------------------------------
# LM block probe: a single transformer block with inner loops UNROLLED.
#
# cost_analysis counts a scan body once regardless of trip count, so the
# full module (layers scanned — compile-cheap) undercounts per-layer work.
# The probe compiles ONE block exactly (attention chunk loops unrolled,
# fwd[+bwd for train]); the dry-run reports
#     corrected = full_module + (n_layers - 1) * probe
# for FLOPs / bytes / collective bytes.  Memory comes from the full module
# (scan memory IS the runtime memory).  Residual error: the one block
# counted inside the full module still undercounts its inner chunk loops —
# bounded by 1/n_layers of attention cost; noted in EXPERIMENTS.md.
# ---------------------------------------------------------------------------

def build_lm_probe(arch: str, shape_name: str, mesh) -> Cell:
    cfg = config_registry.get_config(arch, shape_name)
    shape = shape_by_name("lm", shape_name)
    rules = rules_for_shape(cfg, shape, mesh)
    ctx = ParallelCtx(mesh, rules)
    # probes unroll the attention chunk loops; use LARGE chunks so the
    # unroll count stays small (flash FLOPs are tiling-invariant, so the
    # count is exact either way; only compile time is at stake).
    cfg = dataclasses.replace(
        cfg, attn_unroll=True,
        attn_chunk_q=max(cfg.attn_chunk_q, 4096),
        attn_chunk_kv=max(cfg.attn_chunk_kv, 8192))
    dt = jnp.dtype(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len

    block_sds, block_axes = abstract_init(
        lambda k, c: T.init_block(k, c, dt), jax.random.PRNGKey(0), cfg)
    bp_shard = params_sharding(block_axes, ctx)

    if shape.kind in ("train", "prefill"):
        x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
        x_shard = ctx.sharding("batch", "seq_act", None)
        positions = None

        if shape.kind == "train":
            def probe(bp, x):
                def loss(bp_):
                    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
                    fn = jax.checkpoint(
                        lambda b_, x_: T.block_apply(b_, x_, pos, cfg, ctx))
                    y, aux = fn(bp_, x)
                    return jnp.sum(y.astype(jnp.float32) ** 2) + aux
                l, g = jax.value_and_grad(loss)(bp)
                return l, g
        else:
            def probe(bp, x):
                pos = jnp.broadcast_to(jnp.arange(s), (b, s))
                y, aux = T.block_apply(bp, x, pos, cfg, ctx)
                return y

        return Cell(probe, (block_sds, x_sds), (bp_shard, x_shard), cfg, shape)

    # decode probe: one block's single-token step against the cache slice.
    dcfg = dataclasses.replace(cfg, attn_chunk_q=1, attn_chunk_kv=s)
    from repro.models import layers as LY

    x_sds = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
    x_shard = ctx.sharding("batch", None, None)
    if cfg.attention == "mla":
        cache_sds = (jax.ShapeDtypeStruct((b, s, cfg.kv_lora_rank), dt),
                     jax.ShapeDtypeStruct((b, s, cfg.qk_rope_head_dim), dt))
        c_shard = (ctx.sharding("batch", "kv_seq", None),
                   ctx.sharding("batch", "kv_seq", None))

        def probe(bp, x, cache):
            h = LY.rmsnorm(bp["ln1"], x, dcfg.norm_eps)
            att, ckv, kpe = LY.mla_decode(bp["attn"], h, cache[0], cache[1],
                                          s - 1, dcfg, ctx)
            x = x + att
            return T._block_mlp(bp, x, dcfg, ctx), (ckv, kpe)
    else:
        dh = cfg.resolved_head_dim
        cache_sds = (jax.ShapeDtypeStruct((b, s, cfg.n_kv_heads, dh), dt),
                     jax.ShapeDtypeStruct((b, s, cfg.n_kv_heads, dh), dt))
        c_shard = (ctx.sharding("batch", "kv_seq", "kv_heads", None),
                   ctx.sharding("batch", "kv_seq", "kv_heads", None))

        def probe(bp, x, cache):
            h = LY.rmsnorm(bp["ln1"], x, dcfg.norm_eps)
            att, ck, cv = LY.gqa_decode(bp["attn"], h, cache[0], cache[1],
                                        s - 1, dcfg, ctx)
            x = x + att
            return T._block_mlp(bp, x, dcfg, ctx), (ck, cv)

    return Cell(probe, (block_sds, x_sds, cache_sds),
                (bp_shard, x_shard, c_shard), dcfg, shape)


# ---------------------------------------------------------------------------
# Entry point.
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh) -> Cell:
    cfg = config_registry.get_config(arch, shape_name)
    family = cfg.family
    shape = shape_by_name(family, shape_name)
    rules = rules_for_shape(cfg, shape, mesh)
    ctx = ParallelCtx(mesh, rules)
    if family == "lm":
        return _lm_cell(cfg, shape, ctx)
    if family == "gnn":
        return _gnn_cell(cfg, shape, ctx)
    return _recsys_cell(cfg, shape, ctx)


def all_cells():
    """All 40 (arch, shape) pairs."""
    out = []
    for arch in config_registry.all_archs():
        cfg = config_registry.get_config(arch)
        for s in cfg.shapes:
            out.append((arch, s.name))
    return out
