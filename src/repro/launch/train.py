"""Fault-tolerant training driver.

Composes the substrates into the production loop:

  mesh -> shardings -> init-or-resume -> [step, monitor, checkpoint] x N

Fault tolerance contract (exercised by tests/test_train_driver.py):
  * auto-resume from the latest atomic checkpoint (torn saves impossible);
  * straggler monitor flags persistently slow ranks; the driver's policy
    hook decides (log / evict+re-mesh via distributed.elastic);
  * on unhandled step failure the driver restores the last checkpoint and
    continues (skip-batch-and-go), bounded by ``max_restarts``.

Usage (smoke scale, CPU):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 20 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_registry
from repro.checkpoint import CheckpointManager
from repro.distributed.mesh_utils import local_mesh
from repro.distributed.sharding import ParallelCtx, params_sharding
from repro.distributed.straggler import StragglerMonitor
from repro.data.pipeline import lm_batches, device_put_batch
from repro.launch.steps import make_lm_train_step, _opt_axes_safe
from repro.models import transformer as T


def train_lm(cfg, mesh, steps: int, ckpt_dir: str | None,
             batch_size: int = 8, seq_len: int = 128, lr: float = 3e-4,
             ckpt_interval: int = 10, max_restarts: int = 3,
             log_every: int = 5, seed: int = 0):
    rules = dict(cfg.rules)
    ctx = ParallelCtx(mesh, rules)
    step_fn, opt = make_lm_train_step(cfg, ctx, lr=lr)

    key = jax.random.PRNGKey(seed)
    params, axes = T.init_transformer(key, cfg)
    opt_state = opt.init(params)
    p_shard = params_sharding(axes, ctx)
    if mesh is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            params, p_shard)

    mgr = (CheckpointManager(ckpt_dir, interval=ckpt_interval, use_async=False)
           if ckpt_dir else None)
    start_step = 0
    if mgr is not None:
        start_step, restored = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        if start_step:
            print(f"[train] resumed from step {start_step}")

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    data = lm_batches(
        np.random.default_rng(seed).integers(
            0, cfg.vocab_size, size=500_000).astype(np.int32),
        batch_size, seq_len, seed=seed)

    monitor = StragglerMonitor()
    restarts = 0
    losses = []
    step = start_step
    while step < steps:
        batch = device_put_batch(next(data))
        monitor.step_begin()
        try:
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
        except Exception as e:  # noqa: BLE001
            restarts += 1
            if mgr is None or restarts > max_restarts:
                raise
            print(f"[train] step {step} failed ({e}); restoring last checkpoint")
            s, restored = mgr.restore_latest({"params": params, "opt": opt_state})
            params, opt_state, step = restored["params"], restored["opt"], s
            continue
        flagged = monitor.step_end(step)
        if flagged:
            print(f"[train] straggler ranks flagged at step {step}: {flagged} "
                  f"(policy: evict + re-mesh via distributed.elastic)")
        losses.append(loss)
        step += 1
        if step % log_every == 0:
            print(f"[train] step {step}: loss {loss:.4f}")
        if mgr is not None and mgr.should_save(step):
            mgr.save(step, {"params": params, "opt": opt_state})
    if mgr is not None:
        mgr.save(steps, {"params": params, "opt": opt_state})
        mgr.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = (config_registry.get_smoke_config(args.arch) if args.smoke
           else config_registry.get_config(args.arch))
    mesh = local_mesh() if len(jax.devices()) > 1 else None
    t0 = time.time()
    _, losses = train_lm(cfg, mesh, args.steps, args.ckpt_dir,
                         batch_size=args.batch, seq_len=args.seq)
    print(f"[train] {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
