import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / roofline terms.

MUST be run as a script (the XLA_FLAGS line above executes before any jax
import, including the ones below).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

With no filters it sweeps all 40 cells on the single-pod (16, 16) mesh and
then the multi-pod (2, 16, 16) mesh.  Results land in one JSON per cell.
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False) -> dict:
    import jax
    from repro import configs as config_registry
    from repro.launch.mesh import make_production_mesh
    from repro.launch import roofline as RL
    from repro.launch.steps import build_cell, build_lm_probe

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    try:
        cell = build_cell(arch, shape_name, mesh)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            cost = RL.cost_dict(compiled)
            full_flops = float(cost.get("flops", 0.0))
            full_bytes = float(cost.get("bytes accessed", 0.0))
            full_coll = RL.collective_bytes_from_hlo(hlo)

            # LM layer-scan correction: + (L-1) x exact single-block probe
            probe_info = None
            family = getattr(cell.cfg, "family", "lm")
            if family == "lm":
                probe = build_lm_probe(arch, shape_name, mesh)
                pc = jax.jit(probe.fn, in_shardings=probe.in_shardings
                             ).lower(*probe.args).compile()
                p_cost = RL.cost_dict(pc)
                p_hlo = pc.as_text()
                p_coll = RL.collective_bytes_from_hlo(p_hlo)
                lcount = cell.cfg.n_layers
                full_flops += (lcount - 1) * float(p_cost.get("flops", 0.0))
                full_bytes += (lcount - 1) * float(p_cost.get("bytes accessed", 0.0))
                for k in full_coll:
                    full_coll[k] += (lcount - 1) * p_coll.get(k, 0)
                probe_info = {
                    "probe_flops": float(p_cost.get("flops", 0.0)),
                    "probe_bytes": float(p_cost.get("bytes accessed", 0.0)),
                    "probe_collective": p_coll,
                    "layers": lcount,
                }

            resident = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
            mf = RL.model_flops_for(cell.cfg, cell.shape)
            roof = RL.analyze_terms(full_flops, full_bytes, full_coll,
                                    n_chips, model_flops=mf,
                                    resident_bytes=float(resident))
        record.update(
            ok=True,
            lower_s=round(t_lower - t0, 1),
            compile_s=round(t_compile - t_lower, 1),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total": (mem.argument_size_in_bytes
                                     + mem.output_size_in_bytes
                                     + mem.temp_size_in_bytes
                                     - mem.alias_size_in_bytes),
            },
            roofline=roof.to_dict(),
            probe=probe_info,
        )
        if save_hlo:
            with open(os.path.join(out_dir, f"{arch}__{shape_name}__{record['mesh']}.hlo"),
                      "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — dry-run reports, doesn't die
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{record['mesh']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=2, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose JSON already records ok=true")
    args = ap.parse_args()

    from repro.launch.steps import all_cells

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_fail = 0
    for mp in meshes:
        for arch, shape in cells:
            mesh_tag = "2x16x16" if mp else "16x16"
            if args.skip_existing:
                p = os.path.join(args.out, f"{arch}__{shape}__{mesh_tag}.json")
                if os.path.exists(p):
                    with open(p) as f:
                        if json.load(f).get("ok"):
                            print(f"[SKIP] {arch:24s} {shape:16s} {mesh_tag}",
                                  flush=True)
                            continue
            rec = run_cell(arch, shape, mp, args.out, args.save_hlo)
            status = "OK " if rec["ok"] else "FAIL"
            extra = ""
            if rec["ok"]:
                r = rec["roofline"]
                extra = (f" mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB"
                         f" compute={r['compute_s']*1e3:.2f}ms"
                         f" mem[{(r['memory_lower_s'] or 0)*1e3:.2f}"
                         f",{r['memory_s']*1e3:.2f}]ms"
                         f" coll={r['collective_s']*1e3:.2f}ms"
                         f" bound={r['bottleneck_lower']}/{r['bottleneck']}"
                         f" useful={r['useful_ratio'] and round(r['useful_ratio'],3)}")
            else:
                n_fail += 1
                extra = " " + rec["error"][:160]
            print(f"[{status}] {arch:24s} {shape:16s} {rec['mesh']:8s}"
                  f" {rec['total_s']:7.1f}s{extra}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
