"""Production mesh construction (dry-run + launch entry point).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state."""

from __future__ import annotations

from repro.distributed.mesh_utils import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
