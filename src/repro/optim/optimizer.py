"""Optimizers: AdamW and Adafactor, pure-JAX pytree implementations.

Sharding-preserving: optimizer states mirror parameter shapes, so GSPMD
propagates parameter shardings onto them (Adafactor's factored second
moments shrink the arctic-480B state by ~3 orders of magnitude — the reason
its config selects it; DESIGN.md §5).

Schedules are plain callables step -> lr so they can be traced inside the
jitted train step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    m: object
    v: object


def _adamw_init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamState(jnp.zeros((), jnp.int32), jax.tree.map(z, params),
                     jax.tree.map(z, params))


def _adamw_update(grads, state: AdamState, params, lr, b1=0.9, b2=0.95,
                  eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamState(step, new_m, new_v)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — factored second moments, no first
# moment: O(n+m) state for an n x m matrix.
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: object     # row factors (or full v for <2D leaves)
    vc: object     # col factors (zeros-placeholder for <2D leaves)


def _fact_init(p):
    if p.ndim >= 2:
        return (jnp.zeros(p.shape[:-1], jnp.float32),
                jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32))
    return (jnp.zeros_like(p, dtype=jnp.float32), jnp.zeros((1,), jnp.float32))


def _adafactor_init(params):
    pairs = jax.tree.map(_fact_init, params)
    vr = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    vc = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return AdafactorState(jnp.zeros((), jnp.int32), vr, vc)


def _adafactor_update(grads, state: AdafactorState, params, lr,
                      decay=0.8, eps=1e-30, weight_decay=0.0, clip_thr=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g / jnp.sqrt(jnp.maximum(r[..., None] * vc[..., None, :], eps))
        else:
            vr = beta * vr + (1 - beta) * g2
            u = g / jnp.sqrt(jnp.maximum(vr, eps))
        # update clipping (RMS <= clip_thr)
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / clip_thr)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_vr = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_vc = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdafactorState(step, new_vr, new_vc)


# ---------------------------------------------------------------------------
# Facade.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable           # (grads, state, params, lr) -> (params, state)
    clip_norm: float = 1.0

    def step(self, grads, state, params, lr):
        if self.clip_norm:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        return self.update(grads, state, params, lr)


def make_optimizer(name: str, clip_norm: float = 1.0, **kw) -> Optimizer:
    if name == "adamw":
        return Optimizer("adamw", _adamw_init,
                         functools.partial(_adamw_update, **kw), clip_norm)
    if name == "adafactor":
        return Optimizer("adafactor", _adafactor_init,
                         functools.partial(_adafactor_update, **kw), clip_norm)
    raise ValueError(name)
