from repro.optim.optimizer import Optimizer, make_optimizer, cosine_schedule  # noqa: F401
