"""Gradient compression for cross-pod data parallelism.

Cross-pod links (DCN) are an order of magnitude slower than intra-pod ICI,
so the training driver can compress the *pod-level* gradient exchange:

  * ``topk``  — magnitude top-k sparsification with **error feedback**
    (Stich et al. 2018): the un-transmitted residual is added back into the
    next step's gradient, preserving convergence (test:
    ``tests/test_optim.py`` shows EF closes the convergence gap on a
    quadratic).
  * ``int8``  — per-leaf symmetric int8 quantisation with f32 scale
    (8x wire reduction, unbiased up to rounding).

Both are expressed as pytree transforms ``compress -> (wire, aux)`` /
``decompress`` so they can wrap any collective.  In the GSPMD training
step, cross-pod gradient reduction is implicit; ``repro.launch.train``
applies compression in the explicit shard_map DP-reduce variant and the
effect on the collective roofline term is reported in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TopKCompressed(NamedTuple):
    values: jax.Array
    indices: jax.Array
    shape: tuple


def topk_compress(g: jax.Array, ratio: float) -> TopKCompressed:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return TopKCompressed(flat[idx], idx.astype(jnp.int32), g.shape)


def topk_decompress(c: TopKCompressed) -> jax.Array:
    n = 1
    for s in c.shape:
        n *= s
    flat = jnp.zeros((n,), c.values.dtype).at[c.indices].set(c.values)
    return flat.reshape(c.shape)


def ef_topk_step(g: jax.Array, residual: jax.Array, ratio: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback top-k: returns (transmitted gradient, new residual)."""
    corrected = g + residual
    wire = topk_decompress(topk_compress(corrected, ratio))
    return wire, corrected - wire


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def ef_compress_tree(grads, residuals, ratio: float):
    out = jax.tree.map(lambda g, r: ef_topk_step(g.astype(jnp.float32), r, ratio),
                       grads, residuals)
    wire = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return wire, res


class Int8Compressed(NamedTuple):
    q: jax.Array
    scale: jax.Array


def int8_compress(g: jax.Array) -> Int8Compressed:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    return Int8Compressed(jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8),
                          scale)


def int8_decompress(c: Int8Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def int8_roundtrip_tree(grads):
    return jax.tree.map(lambda g: int8_decompress(int8_compress(g.astype(jnp.float32))),
                        grads)
