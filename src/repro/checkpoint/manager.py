"""Checkpoint lifecycle: retention, auto-resume, async save.

The manager is the training driver's fault-tolerance interface:

  * ``maybe_save(step, tree)`` — periodic + final saves, optionally on a
    background thread (async) so the accelerator never blocks on disk;
  * ``restore_latest(target)`` — resume after restart; scans the directory,
    skips torn checkpoints (no manifest — impossible after atomic rename,
    but scanned defensively), returns (step, tree) or (0, target);
  * retention — keep the newest ``max_to_keep`` checkpoints.
"""

from __future__ import annotations

import concurrent.futures
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax

from repro.checkpoint.checkpoint import (restore_checkpoint, save_checkpoint,
                                         checkpoint_step)

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, interval: int = 100, max_to_keep: int = 3,
                 use_async: bool = False):
        self.directory = directory
        self.interval = interval
        self.max_to_keep = max_to_keep
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if use_async else None)
        self._pending: Optional[concurrent.futures.Future] = None
        os.makedirs(directory, exist_ok=True)

    # -- enumeration -------------------------------------------------------

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_path(self) -> Optional[str]:
        steps = self.all_steps()
        return (os.path.join(self.directory, f"step_{steps[-1]:010d}")
                if steps else None)

    # -- save --------------------------------------------------------------

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def save(self, step: int, tree: Any):
        # materialise on host BEFORE handing to the async thread: the caller
        # may donate/overwrite device buffers on the next step.
        host_tree = jax.tree.map(lambda x: jax.device_get(x), tree)
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(self._save_sync, step, host_tree)
        else:
            self._save_sync(step, host_tree)

    def _save_sync(self, step: int, tree: Any):
        save_checkpoint(self.directory, step, tree)
        self._gc()

    def maybe_save(self, step: int, tree: Any) -> bool:
        if self.should_save(step):
            self.save(step, tree)
            return True
        return False

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------

    def restore_latest(self, target: Any, shardings: Any = None
                       ) -> Tuple[int, Any]:
        path = self.latest_path()
        if path is None:
            return 0, target
        return checkpoint_step(path), restore_checkpoint(path, target, shardings)
