"""Sharding-aware, topology-independent checkpointing.

Checkpoints are saved in *logical* (unsharded) form: one ``.npy`` per pytree
leaf keyed by its tree path, plus a msgpack manifest (tree structure, dtypes,
step).  Restore re-shards each leaf for whatever mesh the restoring job
runs — this is what makes elastic re-scaling (``distributed/elastic.py``)
trivial: a 512-chip checkpoint restores onto 256 chips or 8 CPU devices
unchanged.

Writes are atomic (tmp dir + rename) so a crash mid-save can never corrupt
the latest-good checkpoint — the fault-tolerance contract the training
driver (``launch/train.py``) relies on for restart-on-failure.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomically save ``tree`` under ``directory/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves, _ = _flatten_with_paths(tree)
        manifest = {"step": step, "leaves": {}}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                       "shape": list(arr.shape)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def restore_checkpoint(path: str, target_tree: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target_tree``; optionally place each
    leaf with the given shardings tree (None = default device placement)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten_with_paths(target_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves, _ = _flatten_with_paths(
            jax.tree.map(lambda s: s, shardings,
                         is_leaf=lambda x: x is None or hasattr(x, "spec")))
    restored = {}
    for key, ref in leaves.items():
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(path, info["file"]))
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs "
                             f"target {ref.shape}")
        sh = shard_leaves.get(key) if shard_leaves else None
        if sh is not None:
            restored[key] = jax.device_put(arr.astype(ref.dtype), sh)
        else:
            restored[key] = jnp.asarray(arr.astype(ref.dtype))
    flat, treedef2 = jax.tree_util.tree_flatten(target_tree)
    ordered = []
    flat_paths, _ = jax.tree_util.tree_flatten_with_path(target_tree)
    for path, _ in flat_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        ordered.append(restored[key])
    return jax.tree_util.tree_unflatten(treedef2, ordered)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
