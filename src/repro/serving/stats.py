"""Per-stage serving instrumentation.

Every stage of the serving funnel (admission queue wait, batch execution,
end-to-end request latency) records into a bounded reservoir; a
:meth:`ServingStats.snapshot` call freezes everything into plain
dataclasses with p50/p99/mean, batch-occupancy and close-reason counters,
cache hit-rate, and live queue depth — the numbers the latency/throughput
frontier bench (``benchmarks/serve_bench.py``) and the load-generator
example report.

Overload observability: endpoints with a bounded admission queue also
report their depth limit and exact rejected/shed totals, so a dashboard
can tell "p99 is high because we're queueing" from "p99 is fine because
we're dropping load" — the e2e percentiles cover only *served* requests;
rejected/shed requests never reach the latency reservoirs.

Endpoints registered with an execution backend also surface its identity
string in snapshots, so a latency regression can be attributed to the
path (reference / streaming / pallas) actually serving the endpoint.

All recorders are thread-safe: requests are admitted from client threads
while batcher worker threads record execution.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, Optional

import numpy as np

__all__ = ["LatencySummary", "EndpointSnapshot", "ServiceSnapshot",
           "ServingStats"]

_RESERVOIR = 8192


@dataclasses.dataclass(frozen=True)
class LatencySummary:
    """Percentiles over the (bounded) most recent samples of one stage."""

    count: int = 0
    mean_ms: float = 0.0
    p50_ms: float = 0.0
    p99_ms: float = 0.0

    @staticmethod
    def from_samples(samples_s) -> "LatencySummary":
        if not samples_s:
            return LatencySummary()
        ms = 1e3 * np.asarray(samples_s, dtype=np.float64)
        return LatencySummary(
            count=int(ms.size),
            mean_ms=float(ms.mean()),
            p50_ms=float(np.percentile(ms, 50)),
            p99_ms=float(np.percentile(ms, 99)),
        )


@dataclasses.dataclass(frozen=True)
class EndpointSnapshot:
    name: str
    n_requests: int
    n_batches: int
    mean_batch_fill: float          # served slots / capacity, in [0, 1]
    closed_by_size: int
    closed_by_deadline: int
    closed_by_drain: int
    queue_depth: int                # live depth at snapshot time
    queue_wait: LatencySummary      # admission -> batch close
    execute: LatencySummary         # batch assembly + pipeline run
    e2e: LatencySummary             # admission -> result available
    # exact lifetime sums (the percentile reservoirs are bounded)
    queue_wait_total_s: float = 0.0
    execute_total_s: float = 0.0
    # admission control (exact lifetime counters)
    depth_limit: Optional[int] = None   # None = unbounded queue
    rejected: int = 0               # submits refused under policy "reject"
    shed: int = 0                   # queued requests evicted ("shed_oldest")
    # execution-backend identity serving this endpoint (None = opaque
    # runner / no backend declared at registration)
    backend: Optional[str] = None
    # corpus residency dtype behind this endpoint ("float32"/"bfloat16";
    # None = opaque runner / no dtype declared) — the precision tier a
    # latency or quality delta should be attributed to
    corpus_dtype: Optional[str] = None
    # tuned-profile tag when the endpoint was registered with
    # register_pipeline(profile=...) / register_runner(profile=...) —
    # provenance for every number above (None = hand-configured)
    profile: Optional[str] = None
    # process-wide warm-cache counters at snapshot time ({size, hits,
    # misses}): the pallas tile auto-tune cache and the ANN index LRU.
    # Shared across endpoints (the caches are module-level), surfaced
    # here so the autotuner — and operators — can tell a warm
    # measurement from one paying cold builds/tuning sweeps.
    tile_cache: Optional[Dict[str, int]] = None
    ann_index_cache: Optional[Dict[str, int]] = None
    # live-corpus freshness (None on frozen endpoints): the snapshot
    # generation currently served, per-segment row counts
    # ({"main": ..., "append": ...}), resident tombstoned rows, lifetime
    # compaction count + latency percentiles, and how long ago the
    # served snapshot was swapped in — the numbers that tell "results
    # are fresh" from "the compactor is falling behind the write rate"
    generation: Optional[int] = None
    segment_rows: Optional[Dict[str, int]] = None
    tombstones: Optional[int] = None
    compactions: Optional[int] = None
    compaction: Optional[LatencySummary] = None
    snapshot_age_s: Optional[float] = None
    # staged-funnel observability (None on endpoints that don't record
    # stages): per-stage latency percentiles over batch executions
    # ({"candgen": ..., "fusion": ..., "rerank": ...}), exact lifetime
    # fallback counters (a stage was *skipped* under its budget — the
    # batch was served from the previous stage's output), exact lifetime
    # overrun counters (the stage ran but blew its soft deadline), and
    # per-stage batch occupancy — the fraction of batches that executed
    # the stage (a rerank occupancy of 0.7 with fallbacks covering the
    # other 0.3 is a funnel degrading under load, never silently)
    stages: Optional[Dict[str, LatencySummary]] = None
    stage_fallbacks: Optional[Dict[str, int]] = None
    stage_overruns: Optional[Dict[str, int]] = None
    stage_occupancy: Optional[Dict[str, float]] = None


@dataclasses.dataclass(frozen=True)
class ServiceSnapshot:
    endpoints: Dict[str, EndpointSnapshot]
    n_requests: int
    cache_hits: int
    cache_misses: int
    uptime_s: float

    @property
    def cache_hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0

    @property
    def qps(self) -> float:
        return self.n_requests / self.uptime_s if self.uptime_s > 0 else 0.0


class _EndpointStats:
    def __init__(self, name: str):
        self.name = name
        self.n_requests = 0
        self.n_batches = 0
        self.fill_sum = 0.0
        self.closed_by = collections.Counter()
        self.queue_wait = collections.deque(maxlen=_RESERVOIR)
        self.execute = collections.deque(maxlen=_RESERVOIR)
        self.e2e = collections.deque(maxlen=_RESERVOIR)
        self.queue_wait_total_s = 0.0
        self.execute_total_s = 0.0
        self.overload = collections.Counter()   # "rejected" / "shed"
        # staged-funnel recorders, keyed by stage name ("candgen" /
        # "fusion" / "rerank"): latency reservoirs, exact execution /
        # fallback / overrun counters
        self.stage_lat: Dict[str, collections.deque] = {}
        self.stage_runs = collections.Counter()
        self.stage_fallbacks = collections.Counter()
        self.stage_overruns = collections.Counter()


class ServingStats:
    """Thread-safe recorder; ``snapshot()`` is the only read path."""

    def __init__(self, time_fn: Callable[[], float] = time.monotonic):
        self._lock = threading.Lock()
        self._time_fn = time_fn
        self._t0 = time_fn()
        self._endpoints: Dict[str, _EndpointStats] = {}
        self._depth_fns: Dict[str, Callable[[], int]] = {}
        self._depth_limits: Dict[str, int] = {}
        self._backends: Dict[str, str] = {}
        self._corpus_dtypes: Dict[str, str] = {}
        self._profiles: Dict[str, str] = {}
        self._live_fns: Dict[str, Callable[[], Dict]] = {}
        self.cache_hits = 0
        self.cache_misses = 0

    # -- wiring -------------------------------------------------------------
    def register_endpoint(self, name: str,
                          depth_fn: Optional[Callable[[], int]] = None,
                          depth_limit: Optional[int] = None,
                          backend: Optional[str] = None,
                          corpus_dtype: Optional[str] = None,
                          profile: Optional[str] = None,
                          live_fn: Optional[Callable[[], Dict]] = None):
        """``live_fn`` (``LiveCorpus.live_stats``) makes this endpoint
        report live-corpus freshness in its snapshots."""
        with self._lock:
            self._endpoints.setdefault(name, _EndpointStats(name))
            if depth_fn is not None:
                self._depth_fns[name] = depth_fn
            if depth_limit is not None:
                self._depth_limits[name] = depth_limit
            if backend is not None:
                self._backends[name] = backend
            if corpus_dtype is not None:
                self._corpus_dtypes[name] = corpus_dtype
            if profile is not None:
                self._profiles[name] = profile
            if live_fn is not None:
                self._live_fns[name] = live_fn

    def _ep(self, name: str) -> _EndpointStats:
        return self._endpoints.setdefault(name, _EndpointStats(name))

    def reset(self):
        """Zero all counters/reservoirs (e.g. after a warm-up phase) while
        keeping endpoint registrations and depth probes."""
        with self._lock:
            for name in self._endpoints:
                self._endpoints[name] = _EndpointStats(name)
            self.cache_hits = 0
            self.cache_misses = 0
            self._t0 = self._time_fn()

    # -- recorders ----------------------------------------------------------
    def record_request(self, endpoint: str):
        with self._lock:
            self._ep(endpoint).n_requests += 1

    def record_cache(self, hit: bool):
        with self._lock:
            if hit:
                self.cache_hits += 1
            else:
                self.cache_misses += 1

    def record_batch(self, endpoint: str, *, served: int, capacity: int,
                     closed_by: str, queue_waits_s, exec_s: float):
        with self._lock:
            ep = self._ep(endpoint)
            ep.n_batches += 1
            ep.fill_sum += served / capacity
            ep.closed_by[closed_by] += 1
            ep.queue_wait.extend(queue_waits_s)
            ep.execute.append(exec_s)
            ep.queue_wait_total_s += sum(queue_waits_s)
            ep.execute_total_s += exec_s

    def record_e2e(self, endpoint: str, seconds: float):
        with self._lock:
            self._ep(endpoint).e2e.append(seconds)

    def record_overload(self, endpoint: str, kind: str):
        """``kind`` is ``"rejected"`` or ``"shed"``."""
        with self._lock:
            self._ep(endpoint).overload[kind] += 1

    def record_stage(self, endpoint: str, stage: str,
                     seconds: Optional[float] = None, *,
                     fallback: bool = False, overrun: bool = False):
        """One funnel stage's outcome for one batch.  ``seconds`` set
        means the stage executed (latency sample + occupancy count);
        ``fallback`` means it was skipped under its budget and the batch
        was served from the previous stage's output; ``overrun`` means it
        ran but exceeded its soft deadline.  Called from batcher worker
        threads via the funnel run wrapper."""
        with self._lock:
            ep = self._ep(endpoint)
            if seconds is not None:
                ep.stage_lat.setdefault(
                    stage, collections.deque(maxlen=_RESERVOIR)
                ).append(seconds)
                ep.stage_runs[stage] += 1
            if fallback:
                ep.stage_fallbacks[stage] += 1
            if overrun:
                ep.stage_overruns[stage] += 1

    # -- read path ----------------------------------------------------------
    def snapshot(self) -> ServiceSnapshot:
        # outside the lock: the warm-cache counters have their own locks,
        # and backends is a lazy import so stats stays numpy-only until a
        # snapshot is actually taken
        from repro.core.backends import ann_index_cache_info, tile_cache_info

        tile_cache = tile_cache_info()
        ann_cache = ann_index_cache_info()
        # live-corpus probes outside the stats lock too: they read the
        # corpus's atomically-swapped snapshot, no lock ordering to trip
        live_now = {name: fn() for name, fn in list(self._live_fns.items())}
        with self._lock:
            endpoints = {}
            total = 0
            for name, ep in self._endpoints.items():
                depth = self._depth_fns.get(name, lambda: 0)()
                live = live_now.get(name, {})
                staged = bool(ep.stage_lat or ep.stage_fallbacks
                              or ep.stage_overruns)
                stage_names = (set(ep.stage_lat) | set(ep.stage_runs)
                               | set(ep.stage_fallbacks)
                               | set(ep.stage_overruns))
                endpoints[name] = EndpointSnapshot(
                    name=name,
                    n_requests=ep.n_requests,
                    n_batches=ep.n_batches,
                    mean_batch_fill=(ep.fill_sum / ep.n_batches
                                     if ep.n_batches else 0.0),
                    closed_by_size=ep.closed_by["size"],
                    closed_by_deadline=ep.closed_by["deadline"],
                    closed_by_drain=ep.closed_by["drain"],
                    queue_depth=depth,
                    queue_wait=LatencySummary.from_samples(ep.queue_wait),
                    execute=LatencySummary.from_samples(ep.execute),
                    e2e=LatencySummary.from_samples(ep.e2e),
                    queue_wait_total_s=ep.queue_wait_total_s,
                    execute_total_s=ep.execute_total_s,
                    depth_limit=self._depth_limits.get(name),
                    rejected=ep.overload["rejected"],
                    shed=ep.overload["shed"],
                    backend=self._backends.get(name),
                    corpus_dtype=self._corpus_dtypes.get(name),
                    profile=self._profiles.get(name),
                    tile_cache=tile_cache,
                    ann_index_cache=ann_cache,
                    generation=live.get("generation"),
                    segment_rows=live.get("segment_rows"),
                    tombstones=live.get("tombstones"),
                    compactions=live.get("compactions"),
                    compaction=(LatencySummary.from_samples(
                        live["compaction_s"])
                        if "compaction_s" in live else None),
                    snapshot_age_s=live.get("snapshot_age_s"),
                    stages=({s: LatencySummary.from_samples(d)
                             for s, d in ep.stage_lat.items()}
                            if staged else None),
                    stage_fallbacks=({s: ep.stage_fallbacks[s]
                                      for s in stage_names}
                                     if staged else None),
                    stage_overruns=({s: ep.stage_overruns[s]
                                     for s in stage_names}
                                    if staged else None),
                    stage_occupancy=({s: (ep.stage_runs[s] / ep.n_batches
                                          if ep.n_batches else 0.0)
                                      for s in stage_names}
                                     if staged else None),
                )
                total += ep.n_requests
            return ServiceSnapshot(
                endpoints=endpoints,
                n_requests=total,
                cache_hits=self.cache_hits,
                cache_misses=self.cache_misses,
                uptime_s=self._time_fn() - self._t0,
            )
