"""EndpointSpec — the consolidated, validated endpoint registration API.

Endpoint registration had grown to 10+ loose keyword arguments spread
across ``register_runner`` / ``register_pipeline`` (batching, admission
control, execution backend, residency dtype, tuned profile, live corpus
— and now the funnel's serve width and per-stage budgets), with the
legality rules scattered through the service methods.  ``EndpointSpec``
consolidates all of them into ONE frozen, typed value:

* **Validated at construction.**  ``__post_init__`` reuses the
  autotuner's legality oracle (:func:`repro.serving.autotune.
  check_config` over a probe :class:`~repro.serving.autotune.
  ServingConfig`), so the batching/admission/funnel rules live in
  exactly one place — an illegal spec raises ``ValueError`` *before*
  any endpoint state exists, never mid-registration.
* **One value to pass around.**  ``RetrievalService.register_runner`` /
  ``register_pipeline`` accept ``spec=EndpointSpec(...)``; the old
  keyword surface still works as a thin shim that builds a spec via
  :meth:`EndpointSpec.from_kwargs` (same mutual-exclusion rules, same
  error messages).
* **Profiles expand to specs.**  :meth:`~repro.serving.autotune.
  TunedProfile.to_spec` turns an autotuned Pareto-front row into an
  ``EndpointSpec`` — registration no longer re-implements the profile
  expansion; ``dataclasses.replace`` on the result is the supported way
  to override individual knobs (each replace re-validates).

``backend`` may be a :mod:`repro.core.backends` name, identity string,
or ExecutionBackend instance — backend *capability* legality is owned by
the pipeline rebind at registration (``with_backend``), not here, so an
opaque runner can still declare any label.  ``corpus_dtype`` is checked
against the precision contract when it is a plain dtype name; aggregated
labels (``"mixed(bfloat16,float32)"`` from heterogeneous shard pools)
pass through as declarations.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.spaces import canonical_dtype
from repro.serving.autotune import ServingConfig, TunedProfile, check_config
from repro.serving.funnel import StageBudget

__all__ = ["EndpointSpec"]


@dataclasses.dataclass(frozen=True)
class EndpointSpec:
    """Everything one endpoint registration says, as one frozen value.

    ``batch_size`` / ``max_wait_s`` — continuous-batching close knobs;
    ``jit`` — wrap the runner in ``jax.jit`` (rejected for live and
    funnel endpoints, whose run paths are host code);
    ``max_queue`` / ``overload`` — admission control;
    ``backend`` / ``corpus_dtype`` — execution path and residency dtype
    (rebound through the pipeline's seams, or label-only for runners);
    ``profile`` — the :class:`~repro.serving.autotune.TunedProfile` this
    spec was expanded from (provenance: its tag lands in snapshots and
    cache keys);
    ``live`` — a :class:`~repro.serving.live.LiveCorpus` to serve
    (mutually exclusive with backend/corpus_dtype/profile/jit);
    ``budget`` / ``rerank_keep`` — the funnel knobs: per-stage soft
    deadlines (:class:`~repro.serving.funnel.StageBudget`) and the
    served width of the rerank stage, applied to
    :class:`~repro.serving.funnel.FunnelPipeline` endpoints via
    ``with_budget`` / ``with_rerank_keep`` at registration."""

    batch_size: int = 16
    max_wait_s: float = 0.01
    jit: bool = False
    max_queue: Optional[int] = None
    overload: str = "block"
    backend: Optional[Any] = None
    corpus_dtype: Optional[str] = None
    profile: Optional[TunedProfile] = None
    live: Optional[Any] = None
    budget: Optional[StageBudget] = None
    rerank_keep: Optional[int] = None

    def __post_init__(self):
        if self.live is not None:
            if (self.backend is not None or self.corpus_dtype is not None
                    or self.profile is not None):
                raise ValueError(
                    "live= is mutually exclusive with backend=, "
                    "corpus_dtype=, and profile=: a LiveCorpus declares "
                    "its own backends and residency dtype")
            if self.jit:
                raise ValueError(
                    "live endpoints cannot be jitted: the run path pins "
                    "snapshots and reads host state per batch")
        if self.budget is not None and not isinstance(self.budget,
                                                      StageBudget):
            raise TypeError(
                f"budget must be a StageBudget, got "
                f"{type(self.budget).__name__}")
        # one legality oracle: probe the autotuner's check_config with a
        # genome carrying this spec's batching/admission/funnel knobs.
        # The backend gene is a placeholder — backend capability is owned
        # by the pipeline rebind at registration; dtype is probed only
        # when it is a plain name (aggregated "mixed(...)" labels are
        # declarations, not rebind requests).
        dtype = "float32"
        cd = self.corpus_dtype
        if cd is not None and not (isinstance(cd, str) and "(" in cd):
            try:
                dtype = canonical_dtype(cd)     # resolves "bf16" etc.
            except (TypeError, ValueError):
                dtype = cd if isinstance(cd, str) else "float32"
        probe = ServingConfig(
            backend="reference", corpus_dtype=dtype,
            batch_size=self.batch_size, max_wait_s=self.max_wait_s,
            max_queue=self.max_queue, overload=self.overload,
            rerank_keep=self.rerank_keep,
            rerank_budget_ms=(
                None if self.budget is None or self.budget.rerank_s is None
                else 1e3 * self.budget.rerank_s))
        why = check_config(probe, k=1)
        if why is not None:
            raise ValueError(f"illegal endpoint spec: {why}")

    @classmethod
    def from_kwargs(cls, *, batch_size: int = 16, max_wait_s: float = 0.01,
                    jit: bool = False, max_queue: Optional[int] = None,
                    overload: str = "block", backend: Optional[Any] = None,
                    corpus_dtype: Optional[str] = None,
                    profile: Optional[TunedProfile] = None,
                    live: Optional[Any] = None,
                    budget: Optional[StageBudget] = None,
                    rerank_keep: Optional[int] = None) -> "EndpointSpec":
        """The legacy keyword surface, as a spec constructor — the shim
        ``register_runner`` / ``register_pipeline`` route their loose
        kwargs through.  A ``profile`` expands via
        :meth:`~repro.serving.autotune.TunedProfile.to_spec` (explicit
        ``backend`` / ``corpus_dtype`` alongside it are rejected — a
        profile IS those choices); explicit ``budget`` / ``rerank_keep``
        override the profile's funnel genes."""
        if live is not None:
            # exclusivity is re-checked in __post_init__; constructing
            # directly keeps the error messages identical either way
            return cls(batch_size=batch_size, max_wait_s=max_wait_s,
                       jit=jit, max_queue=max_queue, overload=overload,
                       backend=backend, corpus_dtype=corpus_dtype,
                       profile=profile, live=live, budget=budget,
                       rerank_keep=rerank_keep)
        if profile is not None:
            if backend is not None or corpus_dtype is not None:
                raise ValueError(
                    "profile= supplies backend and corpus_dtype; passing "
                    "them explicitly alongside a profile would serve a "
                    "config the profile never measured")
            overrides: dict = {"jit": jit}
            if budget is not None:
                overrides["budget"] = budget
            if rerank_keep is not None:
                overrides["rerank_keep"] = rerank_keep
            return dataclasses.replace(profile.to_spec(), **overrides)
        return cls(batch_size=batch_size, max_wait_s=max_wait_s, jit=jit,
                   max_queue=max_queue, overload=overload, backend=backend,
                   corpus_dtype=corpus_dtype, budget=budget,
                   rerank_keep=rerank_keep)
