"""The served FlexNeuART funnel: staged candgen -> fusion -> neural rerank.

The paper's system is a multi-stage funnel mixing classic and neural
ranking signals: k-NN candidate generation over mixed dense+sparse
spaces, learned fusion weights, then neural re-ranking.  This module
makes that composition ONE served endpoint with per-stage latency
budgets and per-stage observability:

* :class:`FunnelPipeline` composes a candidate generator (any backend
  tier — exact, ``graph_ann``, ``napp``, the kernel beam; a
  :class:`~repro.serving.sharded.ShardedPipeline`; a live-corpus
  generator), an optional learned-weight *fusion* re-ranker
  (``LinearReranker`` / ``TreeReranker`` over coordinate-ascent or
  LambdaMART output), and an optional *neural rerank* stage
  (:class:`~repro.models.encoder.CrossEncoderReranker`).  ``run`` is
  bit-identical to the offline
  :func:`~repro.core.pipeline.apply_rerankers` composition — verified in
  ``tests/test_funnel.py`` — so serving through the funnel never changes
  answers, it only adds budgets and stats.
* :class:`StageBudget` attaches *soft* per-stage deadlines.  Stages that
  already ran and overran are **counted** (never un-run); the rerank
  stage — the one expensive enough to matter — is *predictively* skipped
  when its learned cost estimate (an EWMA over past executions) no
  longer fits the stage or end-to-end budget.  Degradation is graceful
  and loud: the endpoint serves the fused candidates truncated to the
  funnel's output width, the fallback is counted per stage in
  :class:`~repro.serving.stats.EndpointSnapshot`, and no request ever
  errors because a budget tripped.
* One snapshot per batch: the candidate stage resolves the live-corpus
  seam via :func:`~repro.core.pipeline.pin_snapshot`, so the fusion and
  rerank stages score candidate ids from exactly the corpus state that
  produced them.

The serving integration (``RetrievalService.register_pipeline`` accepts
a funnel like any pipeline, directly or through an
:class:`~repro.serving.spec.EndpointSpec`) times each stage on the
batcher worker thread and records into ``ServingStats``; the admission
queue's wait at batch close is handed to ``run`` as ``elapsed_s`` so the
total budget covers the request's whole life, not just compute.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional, Tuple

import jax

from repro.core.brute_force import TopK
from repro.core.pipeline import pin_snapshot

__all__ = ["FUNNEL_STAGES", "StageBudget", "StageTrace", "FunnelPipeline"]

# Stage names, in flow order — the keys under which EndpointSnapshot
# reports per-stage latency, fallback, overrun, and occupancy.
FUNNEL_STAGES = ("candgen", "fusion", "rerank")

# EWMA smoothing for the learned rerank-cost estimate: heavy enough that
# one scheduler hiccup can't flip the skip decision, light enough that a
# genuinely slowed-down reranker is noticed within a few batches.
_EWMA_ALPHA = 0.3


@dataclasses.dataclass(frozen=True)
class StageBudget:
    """Soft per-stage deadlines, in seconds (``None`` = unbounded).

    ``candgen_s`` / ``fusion_s`` overruns are counted (those stages must
    run — there is nothing earlier to degrade to).  ``rerank_s`` bounds
    the rerank stage: once the funnel's cost estimate exceeds it, the
    stage is skipped and the batch is served from the fused candidates
    (counted as a fallback).  ``total_s`` is the end-to-end soft
    deadline covering queue wait + all stages: the rerank stage is
    skipped when the remaining budget no longer fits its estimated
    cost."""

    candgen_s: Optional[float] = None
    fusion_s: Optional[float] = None
    rerank_s: Optional[float] = None
    total_s: Optional[float] = None

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is not None and not v > 0:
                raise ValueError(
                    f"StageBudget.{f.name} must be positive (or None for "
                    f"unbounded), got {v!r}")


_NO_BUDGET = StageBudget()


@dataclasses.dataclass(frozen=True)
class StageTrace:
    """What one funnel run did, stage by stage: wall seconds per executed
    stage (``None`` = stage absent or skipped), whether the rerank stage
    fell back to fused candidates, which stages overran their soft
    deadline, and the human-readable skip reason (diagnostics — the
    counters in the endpoint snapshot are the contract)."""

    candgen_s: float
    fusion_s: Optional[float] = None
    rerank_s: Optional[float] = None
    fallback: bool = False
    overruns: Tuple[str, ...] = ()
    fallback_reason: Optional[str] = None


class FunnelPipeline:
    """candgen -> learned fusion -> neural rerank, as one served unit.

    ``generator`` is anything with ``generate(query_repr, k) -> TopK``
    (a plain candidate generator, a ``ShardedPipeline`` — its merged
    global candidates are then fused and reranked ONCE, after the merge
    — or a ``LiveGenerator``, pinned to one snapshot per run).
    ``fusion`` and ``rerank`` implement the ``Reranker`` protocol;
    ``cand_qty`` / ``fusion_qty`` / ``rerank_keep`` are the funnel
    widths (``cand_qty`` candidates -> ``fusion_qty`` fused ->
    ``rerank_keep`` served).

    Mutable on purpose (unlike ``RetrievalPipeline``): the funnel learns
    its rerank stage's cost online to make the budget decision *before*
    paying the cost.  The estimate is lock-guarded — a funnel registered
    behind several endpoints shares one estimate, which is the point:
    the stage's cost is a property of the model, not the endpoint."""

    def __init__(self, generator, *, fusion=None, rerank=None,
                 cand_qty: int = 100, fusion_qty: int = 50,
                 rerank_keep: int = 10,
                 budget: Optional[StageBudget] = None,
                 time_fn: Callable[[], float] = time.monotonic):
        if cand_qty < fusion_qty or fusion_qty < rerank_keep:
            raise ValueError(
                f"funnel widths must narrow: cand_qty={cand_qty} >= "
                f"fusion_qty={fusion_qty} >= rerank_keep={rerank_keep}")
        self.generator = generator
        self.fusion = fusion
        self.rerank = rerank
        self.cand_qty = cand_qty
        self.fusion_qty = fusion_qty
        self.rerank_keep = rerank_keep
        self.budget = budget
        self._time_fn = time_fn
        self._lock = threading.Lock()
        self._rerank_cost_s: Optional[float] = None

    # -- seams the serving layer rebinds through ----------------------------
    @property
    def backend(self):
        return getattr(self.generator, "backend", None)

    @property
    def corpus_dtype(self):
        return getattr(self.generator, "corpus_dtype", None)

    @property
    def n_shards(self) -> int:
        return getattr(self.generator, "n_shards", 1)

    def _replace(self, **kw) -> "FunnelPipeline":
        merged = dict(generator=self.generator, fusion=self.fusion,
                      rerank=self.rerank, cand_qty=self.cand_qty,
                      fusion_qty=self.fusion_qty,
                      rerank_keep=self.rerank_keep, budget=self.budget,
                      time_fn=self._time_fn)
        merged.update(kw)
        return FunnelPipeline(**merged)

    def with_backend(self, backend) -> "FunnelPipeline":
        """Same funnel stages, different execution path under the
        candidate generator (fresh cost estimate — the stages' inputs
        change shape of work)."""
        if not hasattr(self.generator, "with_backend"):
            raise TypeError(
                f"generator {type(self.generator).__name__} does not take "
                "an execution backend")
        return self._replace(generator=self.generator.with_backend(backend))

    def with_corpus_dtype(self, dtype) -> "FunnelPipeline":
        """Same funnel stages, different corpus residency dtype under the
        candidate generator."""
        if not hasattr(self.generator, "with_corpus_dtype"):
            raise TypeError(
                f"generator {type(self.generator).__name__} does not take "
                "a corpus residency dtype")
        return self._replace(
            generator=self.generator.with_corpus_dtype(dtype))

    def with_budget(self, budget: Optional[StageBudget]) -> "FunnelPipeline":
        """Same funnel, different per-stage budgets (how an
        ``EndpointSpec`` / tuned profile binds budgets at registration)."""
        return self._replace(budget=budget)

    def with_rerank_keep(self, rerank_keep: int) -> "FunnelPipeline":
        """Same funnel, different served width (the ``rerank_keep``
        genome knob of :mod:`repro.serving.autotune`)."""
        return self._replace(rerank_keep=rerank_keep)

    # -- the staged run ------------------------------------------------------
    def _should_skip_rerank(self, estimate: Optional[float], spent_s: float,
                            budget: StageBudget) -> Optional[str]:
        """The predictive degradation decision, made BEFORE paying the
        rerank cost (a stage cannot be un-run).  ``None`` = run the
        stage.  With no estimate yet (first batch) the stage runs and
        seeds the estimate — so a funnel that overruns once is counted
        once, then degrades deterministically."""
        if (budget.rerank_s is not None and estimate is not None
                and estimate > budget.rerank_s):
            return (f"estimated rerank cost {1e3 * estimate:.2f}ms exceeds "
                    f"stage budget {1e3 * budget.rerank_s:.2f}ms")
        if budget.total_s is not None:
            if spent_s >= budget.total_s:
                return (f"e2e budget {1e3 * budget.total_s:.2f}ms already "
                        f"spent ({1e3 * spent_s:.2f}ms) before rerank")
            if estimate is not None and spent_s + estimate > budget.total_s:
                return (f"remaining e2e budget "
                        f"{1e3 * (budget.total_s - spent_s):.2f}ms below "
                        f"estimated rerank cost {1e3 * estimate:.2f}ms")
        return None

    def run_timed(self, query_repr, q_tokens=None, *,
                  elapsed_s: float = 0.0) -> Tuple[TopK, StageTrace]:
        """One batch through the staged funnel; returns the result and
        the per-stage trace the serving layer records.  ``elapsed_s`` is
        time the batch already spent before compute (the admission
        queue's wait at batch close) and counts against ``total_s``.

        Each stage is synced (``block_until_ready``) before its clock
        stops — otherwise JAX's async dispatch would bill every stage's
        work to whichever stage happens to block first."""
        budget = self.budget if self.budget is not None else _NO_BUDGET
        overruns = []
        now = self._time_fn
        t0 = now()
        cands = jax.block_until_ready(
            pin_snapshot(self.generator).generate(query_repr, self.cand_qty))
        candgen_s = now() - t0
        if budget.candgen_s is not None and candgen_s > budget.candgen_s:
            overruns.append("candgen")

        fusion_s = None
        if self.fusion is not None:
            t1 = now()
            cands = jax.block_until_ready(
                self.fusion.rerank(q_tokens, cands, self.fusion_qty))
            fusion_s = now() - t1
            if budget.fusion_s is not None and fusion_s > budget.fusion_s:
                overruns.append("fusion")

        rerank_s = None
        fallback = False
        reason = None
        if self.rerank is not None:
            with self._lock:
                estimate = self._rerank_cost_s
            reason = self._should_skip_rerank(
                estimate, elapsed_s + (now() - t0), budget)
            if reason is not None:
                fallback = True
            else:
                t2 = now()
                cands = jax.block_until_ready(
                    self.rerank.rerank(q_tokens, cands, self.rerank_keep))
                rerank_s = now() - t2
                with self._lock:
                    prev = self._rerank_cost_s
                    self._rerank_cost_s = (
                        rerank_s if prev is None
                        else _EWMA_ALPHA * rerank_s
                        + (1.0 - _EWMA_ALPHA) * prev)
                if (budget.rerank_s is not None
                        and rerank_s > budget.rerank_s):
                    overruns.append("rerank")
        if rerank_s is None:
            # no rerank stage, or it was skipped: serve the fused
            # candidates truncated to the funnel's output width —
            # exactly apply_rerankers' no-final tail, so the degraded
            # result is the fused ranking, never a different answer
            keep = min(self.rerank_keep, cands.scores.shape[1])
            cands = TopK(cands.scores[:, :keep], cands.indices[:, :keep])
        return cands, StageTrace(
            candgen_s=candgen_s, fusion_s=fusion_s, rerank_s=rerank_s,
            fallback=fallback, overruns=tuple(overruns),
            fallback_reason=reason)

    def run(self, query_repr, q_tokens=None, *,
            elapsed_s: float = 0.0) -> TopK:
        """The batched-runner surface (``run(query_repr, q_tokens)``):
        identical results to the offline ``apply_rerankers`` composition
        under a generous (or absent) budget."""
        out, _ = self.run_timed(query_repr, q_tokens, elapsed_s=elapsed_s)
        return out

    # -- observability / lifecycle -------------------------------------------
    @property
    def rerank_cost_estimate_s(self) -> Optional[float]:
        """The current EWMA rerank-cost estimate (None until the stage
        has run once)."""
        with self._lock:
            return self._rerank_cost_s

    def reset_cost_estimates(self):
        """Forget learned stage costs (e.g. after swapping the rerank
        model) so the next batch re-seeds them."""
        with self._lock:
            self._rerank_cost_s = None

    def close(self):
        """Release generator-owned resources (a sharded generator's
        host-parallel pool); no-op otherwise."""
        close = getattr(self.generator, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "FunnelPipeline":
        return self

    def __exit__(self, *exc):
        self.close()
