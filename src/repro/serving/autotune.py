"""Roofline-pruned Pareto autotuner over the serving config space.

The serving stack exposes a combinatorial knob space — execution backend,
``tile_n``, corpus residency dtype, shard count, batch size/deadline,
cache size, admission control, ANN search budgets — that
``benchmarks/serve_bench.py`` only probes with hand-picked grids.  This
module closes the loop (the NMSLIB manual's per-dataset tuning workflow,
applied to our serving layer):

* :class:`ServingConfig` — a typed genome over every serving knob, with
  per-knob legality (:func:`check_config`) derived from the capability
  matrix in :mod:`repro.core.backends` (``graph_ann`` requires
  ``k <= ef``, ``napp`` requires ``k <= rerank_qty``, the kernel
  traversal inherits the Pallas dtype/space matrix and the VMEM beam
  budget, approximate backends tune against a single global index).
* A zero-cost **roofline proxy** (:func:`proxy_objectives`, built on
  :func:`repro.launch.roofline.serving_scan_seconds` /
  :func:`~repro.launch.roofline.serving_visit_seconds`) estimates each
  genome's (throughput, latency, recall) without running it; candidates
  are pruned to a measurement budget by non-dominated proxy rank +
  crowding (:func:`roofline_prune`) before any load test.
* :func:`measure_config` evaluates a survivor under the **real** load
  generator: a fresh :class:`~repro.serving.service.RetrievalService`
  around the planted-cluster corpus, hot-set workload replay, recall
  measured against the exact oracle — and verifies through the endpoint
  snapshot's identity string that the requested backend/dtype actually
  served (a capability fallback raises instead of silently measuring the
  reference path).
* :func:`autotune` evolves the population (mutation + crossover +
  NSGA-II-style non-dominated sorting) toward the measured
  latency/throughput/recall Pareto front.
* :class:`TunedProfile` — a serializable front row that
  ``RetrievalService.register_pipeline(profile=...)`` /
  ``register_runner(profile=...)`` accept, rebinding backend, dtype and
  batching in one shot with the profile tag surfaced in stats snapshots
  and cache keys.

Driver: ``benchmarks/autotune_pareto.py`` (schema-validated
``BENCH_pareto.json``); tests: ``tests/test_autotune.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import backends as backends_lib
from repro.core.backends import (GraphANNBackend, NappBackend, PallasBackend,
                                 ReferenceBackend, StreamingBackend)
from repro.core.spaces import CORPUS_DTYPES, canonical_dtype, cast_corpus
from repro.serving.batcher import OVERLOAD_POLICIES, ServiceOverloaded

__all__ = [
    "ServingConfig",
    "check_config",
    "random_config",
    "mutate",
    "crossover",
    "proxy_objectives",
    "roofline_prune",
    "dominates",
    "pareto_front",
    "nondominated_sort",
    "crowding_distance",
    "MeasuredPoint",
    "measure_config",
    "autotune",
    "AutotuneResult",
    "TunedProfile",
]

# Knob domains the genome operators sample from.  These are search
# *menus*, not legality bounds — legality is check_config, derived from
# the backend capability matrix, so a domain tweak can never emit a
# config the backends would refuse.
GENOME_BACKENDS = ("reference", "streaming", "pallas", "graph_ann", "napp")
GENOME_TILES = (None, 512, 1024, 2048, 4096, 8192)
GENOME_SHARDS = (1, 2, 4)
GENOME_BATCH_SIZES = (1, 2, 4, 8, 16, 32, 64, 128)
GENOME_WAITS_S = (0.0005, 0.001, 0.002, 0.005, 0.01)
GENOME_CACHE_SIZES = (0, 1024, 4096)
GENOME_QUEUES = (None, 32, 128)
GENOME_EFS = (16, 32, 64, 128)
GENOME_HOPS = (None, 2, 4, 8)
GENOME_NUM_SEARCH = (4, 8, 16)
GENOME_RERANK = (64, 128, 256)
# Funnel knobs (only sampled for funnel endpoints — plain genomes keep
# them None so a pipeline config can never differ in dead funnel genes):
GENOME_RERANK_KEEP = (10, 20, 50)
GENOME_RERANK_BUDGETS_MS = (None, 2.0, 5.0, 20.0)

# GraphANNBackend's default graph degree: the proxy's candidate-visit
# count and the kernel beam-budget legality check both need it.
_GRAPH_DEGREE = 16

# Host-side per-batch overhead folded into the proxy's batch time: off
# the accelerator the dispatch/py-overhead term dominates tiny roofline
# times, and without it the proxy's config ranking would be driven by
# nanosecond-scale differences no measurement can reproduce.
_PROXY_BATCH_OVERHEAD_S = 1e-3

# Queue depth (in batches) the proxy assumes for an UNBOUNDED admission
# queue under the flood workload — a request admitted mid-flood waits
# behind this many batches.  A bounded queue caps the backlog at
# max_queue/batch_size instead, which is exactly why bounded-admission
# genomes occupy the low-latency end of the proxy front.
_PROXY_FLOOD_BACKLOG = 8.0


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """One point in the serving config space — the autotuner's genome.

    Backend-scoped knobs are ``None`` (or False) when inapplicable:
    ``tile_n`` exists for streaming/pallas, ``ef``/``hops``/``kernel``
    for graph_ann, ``num_search``/``rerank_qty`` for napp —
    :func:`check_config` rejects out-of-scope knobs, so two configs that
    serve identically can never differ in dead genes."""

    backend: str = "reference"
    tile_n: Optional[int] = None
    corpus_dtype: str = "float32"
    n_shards: int = 1
    batch_size: int = 16
    max_wait_s: float = 0.01
    cache_size: int = 0
    max_queue: Optional[int] = None
    overload: str = "block"
    ef: Optional[int] = None
    hops: Optional[int] = None
    kernel: bool = False
    num_search: Optional[int] = None
    rerank_qty: Optional[int] = None
    # funnel genes (repro.serving.funnel.FunnelPipeline endpoints):
    # rerank_keep = served width of the neural rerank stage,
    # rerank_budget_ms = its soft stage deadline (skip-and-degrade past
    # it).  Both None for plain (non-funnel) serving configs.
    rerank_keep: Optional[int] = None
    rerank_budget_ms: Optional[float] = None

    def key(self) -> tuple:
        """Canonical hashable identity (dedup across generations)."""
        return dataclasses.astuple(self)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServingConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def make_backend(self):
        """The ExecutionBackend instance this genome declares."""
        if self.backend == "reference":
            return ReferenceBackend()
        if self.backend == "streaming":
            return (StreamingBackend(tile_n=self.tile_n)
                    if self.tile_n is not None else StreamingBackend())
        if self.backend == "pallas":
            return PallasBackend(tile_n=self.tile_n)
        if self.backend == "graph_ann":
            return GraphANNBackend(ef=self.ef, hops=self.hops,
                                   kernel=self.kernel)
        if self.backend == "napp":
            # min_times=1: at bench corpus sizes the stricter default
            # intersection threshold empties candidate sets for some
            # queries (ann_tradeoff made the same call)
            return NappBackend(num_search=self.num_search,
                               min_times=1, rerank_qty=self.rerank_qty)
        raise ValueError(f"unknown backend {self.backend!r}")


def check_config(cfg: ServingConfig, k: int, space=None,
                 corpus=None) -> Optional[str]:
    """None if ``cfg`` is a legal genome for top-``k`` serving, else the
    reason — derived from the backend capability matrix, never restated.

    With ``space``/``corpus`` supplied the actual capability check runs
    against the corpus cast to the genome's residency dtype (exactly
    what registration will scan), so a config that would silently fall
    back to reference at registration is illegal here."""
    if cfg.backend not in backends_lib.available_backends():
        return (f"unknown backend {cfg.backend!r}; registered: "
                f"{backends_lib.available_backends()}")
    if cfg.corpus_dtype not in CORPUS_DTYPES:
        return (f"corpus_dtype {cfg.corpus_dtype!r} outside the precision "
                f"contract {CORPUS_DTYPES}")
    if cfg.n_shards < 1:
        return "n_shards must be >= 1"
    if cfg.batch_size < 1:
        return "batch_size must be >= 1"
    if cfg.max_wait_s <= 0:
        return "max_wait_s must be positive"
    if cfg.cache_size < 0:
        return "cache_size must be >= 0"
    if cfg.max_queue is not None and cfg.max_queue < 1:
        return "max_queue must be >= 1 (or None for unbounded)"
    if cfg.overload not in OVERLOAD_POLICIES:
        return f"overload {cfg.overload!r} not in {OVERLOAD_POLICIES}"
    if cfg.max_queue is not None and cfg.max_queue < cfg.batch_size:
        return ("max_queue below batch_size starves the batcher of full "
                "batches")

    tiled = cfg.backend in ("streaming", "pallas")
    if cfg.tile_n is not None:
        if not tiled:
            return f"tile_n applies to streaming/pallas, not {cfg.backend}"
        if cfg.tile_n < 1:
            return "tile_n must be >= 1"

    graph = cfg.backend == "graph_ann"
    if (cfg.ef is not None or cfg.hops is not None or cfg.kernel) and not graph:
        return f"ef/hops/kernel apply to graph_ann, not {cfg.backend}"
    if graph:
        if cfg.ef is None:
            return "graph_ann needs a declared ef budget"
        if k > cfg.ef:
            return (f"graph_ann declared search budget ef={cfg.ef} cannot "
                    f"produce top-{k}")
        if cfg.hops is not None and cfg.hops < 1:
            return "hops must be >= 1 (or None for the auto default)"
        if cfg.kernel:
            from repro.kernels.beam_topk import check_beam_budget
            try:
                check_beam_budget(cfg.ef, _GRAPH_DEGREE)
            except ValueError as exc:
                return str(exc)
            if cfg.corpus_dtype not in PallasBackend._DTYPES:
                return (f"graph_ann kernel path serves "
                        f"{PallasBackend._DTYPES} corpora, "
                        f"not {cfg.corpus_dtype}")

    napp = cfg.backend == "napp"
    if (cfg.num_search is not None or cfg.rerank_qty is not None) and not napp:
        return f"num_search/rerank_qty apply to napp, not {cfg.backend}"
    if napp:
        if cfg.rerank_qty is None:
            return "napp needs a declared rerank_qty budget"
        if k > cfg.rerank_qty:
            return (f"napp declared re-rank budget rerank_qty="
                    f"{cfg.rerank_qty} cannot produce top-{k}")
        if cfg.num_search is None or cfg.num_search < 1:
            return "napp needs num_search >= 1"

    if cfg.backend in ("graph_ann", "napp") and cfg.n_shards != 1:
        return ("approximate backends tune against one global index "
                "(sharding would measure the union-of-shards "
                "approximation and rebuild per-shard indexes per config)")
    if cfg.backend == "pallas" and cfg.corpus_dtype not in PallasBackend._DTYPES:
        return (f"pallas serves {PallasBackend._DTYPES} corpora, "
                f"not {cfg.corpus_dtype}")

    if cfg.rerank_keep is not None and cfg.rerank_keep < k:
        return (f"funnel rerank_keep={cfg.rerank_keep} cannot serve "
                f"top-{k}")
    if cfg.rerank_budget_ms is not None and not cfg.rerank_budget_ms > 0:
        return "rerank_budget_ms must be positive (or None for unbounded)"

    if space is not None and corpus is not None:
        test_corpus = cast_corpus(corpus, canonical_dtype(cfg.corpus_dtype))
        why = cfg.make_backend().supports(space, test_corpus)
        if why is not None:
            return why
    return None


# ---------------------------------------------------------------------------
# Genome operators (mutation / crossover), deterministic in their rng.
# ---------------------------------------------------------------------------

def _choice(rng: np.random.Generator, domain: Sequence):
    return domain[int(rng.integers(len(domain)))]


def _knobs_for(backend: str, funnel: bool = False) -> List[str]:
    knobs = ["backend", "corpus_dtype", "n_shards", "batch_size",
             "max_wait_s", "cache_size", "max_queue", "overload"]
    if backend in ("streaming", "pallas"):
        knobs.append("tile_n")
    if backend == "graph_ann":
        knobs += ["ef", "hops", "kernel"]
    if backend == "napp":
        knobs += ["num_search", "rerank_qty"]
    if funnel:
        knobs += ["rerank_keep", "rerank_budget_ms"]
    return knobs


def _resample(knob: str, rng: np.random.Generator, k: int):
    if knob == "backend":
        return _choice(rng, GENOME_BACKENDS)
    if knob == "corpus_dtype":
        return _choice(rng, CORPUS_DTYPES)
    if knob == "n_shards":
        return _choice(rng, GENOME_SHARDS)
    if knob == "batch_size":
        return _choice(rng, GENOME_BATCH_SIZES)
    if knob == "max_wait_s":
        return _choice(rng, GENOME_WAITS_S)
    if knob == "cache_size":
        return _choice(rng, GENOME_CACHE_SIZES)
    if knob == "max_queue":
        return _choice(rng, GENOME_QUEUES)
    if knob == "overload":
        return _choice(rng, OVERLOAD_POLICIES)
    if knob == "tile_n":
        return _choice(rng, GENOME_TILES)
    if knob == "ef":
        return _choice(rng, [e for e in GENOME_EFS if e >= k])
    if knob == "hops":
        return _choice(rng, GENOME_HOPS)
    if knob == "kernel":
        return bool(rng.integers(2))
    if knob == "num_search":
        return _choice(rng, GENOME_NUM_SEARCH)
    if knob == "rerank_qty":
        return _choice(rng, [r for r in GENOME_RERANK if r >= k])
    if knob == "rerank_keep":
        return _choice(rng, [r for r in GENOME_RERANK_KEEP if r >= k])
    if knob == "rerank_budget_ms":
        return _choice(rng, GENOME_RERANK_BUDGETS_MS)
    raise KeyError(knob)


def _repair(d: Dict[str, Any], rng: np.random.Generator,
            k: int) -> Optional[ServingConfig]:
    """Re-scope backend-specific genes after a backend flip / crossover,
    then run the full legality check.  Returns None when irreparable."""
    backend = d["backend"]
    if backend not in ("streaming", "pallas"):
        d["tile_n"] = None
    if backend != "graph_ann":
        d["ef"], d["hops"], d["kernel"] = None, None, False
    else:
        if d["ef"] is None or d["ef"] < k:
            d["ef"] = _resample("ef", rng, k)
    if backend != "napp":
        d["num_search"], d["rerank_qty"] = None, None
    else:
        if d["num_search"] is None:
            d["num_search"] = _resample("num_search", rng, k)
        if d["rerank_qty"] is None or d["rerank_qty"] < k:
            d["rerank_qty"] = _resample("rerank_qty", rng, k)
    if backend in ("graph_ann", "napp"):
        d["n_shards"] = 1
    if (d["max_queue"] is not None and d["max_queue"] < d["batch_size"]):
        d["max_queue"] = None
    if d.get("rerank_keep") is None:
        # a stage budget without a rerank stage is a dead gene
        d["rerank_budget_ms"] = None
    elif d["rerank_keep"] < k:
        d["rerank_keep"] = _resample("rerank_keep", rng, k)
    cfg = ServingConfig(**d)
    return cfg if check_config(cfg, k) is None else None


def random_config(rng: np.random.Generator, k: int) -> ServingConfig:
    """One uniformly-sampled legal genome."""
    for _ in range(128):
        d = {knob: _resample(knob, rng, k)
             for knob in ("backend", "corpus_dtype", "n_shards",
                          "batch_size", "max_wait_s", "cache_size",
                          "max_queue", "overload")}
        d.update(tile_n=None, ef=None, hops=None, kernel=False,
                 num_search=None, rerank_qty=None,
                 rerank_keep=None, rerank_budget_ms=None)
        if d["backend"] in ("streaming", "pallas"):
            d["tile_n"] = _resample("tile_n", rng, k)
        if d["backend"] == "graph_ann":
            d["ef"] = _resample("ef", rng, k)
            d["hops"] = _resample("hops", rng, k)
            d["kernel"] = _resample("kernel", rng, k)
        if d["backend"] == "napp":
            d["num_search"] = _resample("num_search", rng, k)
            d["rerank_qty"] = _resample("rerank_qty", rng, k)
        cfg = _repair(d, rng, k)
        if cfg is not None:
            return cfg
    raise RuntimeError("could not sample a legal serving config")


def mutate(cfg: ServingConfig, rng: np.random.Generator,
           k: int) -> ServingConfig:
    """Resample one applicable knob (repairing scoped genes); returns a
    legal genome, falling back to ``cfg`` itself if 64 attempts fail."""
    for _ in range(64):
        knob = _choice(rng, _knobs_for(cfg.backend,
                                       funnel=cfg.rerank_keep is not None))
        d = cfg.to_dict()
        d[knob] = _resample(knob, rng, k)
        new = _repair(d, rng, k)
        if new is not None and new != cfg:
            return new
    return cfg


def crossover(a: ServingConfig, b: ServingConfig, rng: np.random.Generator,
              k: int) -> ServingConfig:
    """Uniform crossover: each gene from either parent, then repair.
    Falls back to parent ``a`` when the blend is irreparable."""
    da, db = a.to_dict(), b.to_dict()
    d = {key: (da[key] if rng.integers(2) else db[key]) for key in da}
    new = _repair(d, rng, k)
    return new if new is not None else a


# ---------------------------------------------------------------------------
# Zero-cost roofline proxy.
# ---------------------------------------------------------------------------

def proxy_objectives(cfg: ServingConfig, *, n_docs: int, dim: int, k: int,
                     repeat_fraction: float = 0.0) -> Tuple[float, float, float]:
    """Estimated maximization objectives ``(qps, -latency_s, recall)``
    for one genome, from the roofline model alone — no measurement.

    Exact backends: whole-config scan cost
    (:func:`~repro.launch.roofline.serving_scan_seconds` — bytes/row x
    dtype x shards, tiles, batch amortization).  graph_ann: candidate
    visits ``sqrt(N) + hops * ef * degree`` (entry scoring + beam
    expansion) through the gather roofline
    (:func:`~repro.launch.roofline.serving_visit_seconds`).  napp: the
    pivot-count pass (one narrow int matmul over all rows) plus the
    exact re-rank of ``rerank_qty`` gathered rows.  Proxy recall is 1
    for exact paths and degrades with ``k`` approaching the declared
    budget for approximate ones — a rank signal, not a calibration.

    A cache turns the repeated fraction of the workload into free hits:
    only misses pay the batch cost, so effective qps scales by
    ``1 / (1 - repeat_fraction)``.  Tail latency under a flood is queue
    wait: an unbounded admission queue backs up
    ``_PROXY_FLOOD_BACKLOG`` batches deep, a bounded one caps the
    backlog at ``max_queue / batch_size`` — admission control is a
    latency knob and the proxy ranks it as one."""
    from repro.launch.roofline import (serving_scan_seconds,
                                       serving_visit_seconds)

    itemsize = 2 if cfg.corpus_dtype == "bfloat16" else 4
    bytes_per_row = float(dim * itemsize)
    b = cfg.batch_size
    if cfg.backend == "graph_ann":
        hops = (cfg.hops if cfg.hops is not None
                else max(4, int(2 * math.log(max(n_docs, 2)))))
        visits = math.sqrt(n_docs) + hops * cfg.ef * _GRAPH_DEGREE
        batch_s = serving_visit_seconds(visits, b=b,
                                        bytes_per_row=bytes_per_row,
                                        flops_per_visit=2.0 * dim)
        recall = 1.0 - 0.5 * k / cfg.ef
    elif cfg.backend == "napp":
        # count pass: every row contributes one num_search-wide integer
        # dot against the query's pivot set (4 bytes of posting data per
        # row), then rerank_qty gathered rows are scored exactly
        count_s = serving_scan_seconds(
            n_docs, b=b, k=k, bytes_per_row=4.0,
            flops_per_row=2.0 * b * cfg.num_search)
        rerank_s = serving_visit_seconds(
            cfg.rerank_qty, b=b, bytes_per_row=bytes_per_row,
            flops_per_visit=2.0 * dim)
        batch_s = count_s + rerank_s
        recall = 1.0 - 0.5 * k / cfg.rerank_qty
    else:
        batch_s = serving_scan_seconds(
            n_docs, b=b, k=k, bytes_per_row=bytes_per_row,
            flops_per_row=2.0 * b * dim, tile_n=cfg.tile_n,
            n_shards=cfg.n_shards)
        recall = 1.0
    step_s = batch_s + _PROXY_BATCH_OVERHEAD_S
    miss = 1.0 - (repeat_fraction if cfg.cache_size > 0 else 0.0)
    qps = b / (step_s * max(miss, 0.05))
    backlog = (cfg.max_queue / b if cfg.max_queue is not None
               else _PROXY_FLOOD_BACKLOG)
    latency_s = cfg.max_wait_s + (1.0 + backlog) * step_s
    return (qps, -latency_s, recall)


# ---------------------------------------------------------------------------
# Non-dominated sorting + crowding (NSGA-II machinery).
# ---------------------------------------------------------------------------

def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff maximization vector ``a`` Pareto-dominates ``b``: no
    worse on every objective, strictly better on at least one."""
    return (all(x >= y for x, y in zip(a, b))
            and any(x > y for x, y in zip(a, b)))


def nondominated_sort(objectives: Sequence[Sequence[float]]) -> List[List[int]]:
    """Fast non-dominated sort: list of fronts (index lists), front 0
    first.  Deterministic — indices keep input order within a front."""
    n = len(objectives)
    dominated_by = [0] * n            # how many points dominate i
    dominates_set: List[List[int]] = [[] for _ in range(n)]
    for i in range(n):
        for j in range(i + 1, n):
            if dominates(objectives[i], objectives[j]):
                dominates_set[i].append(j)
                dominated_by[j] += 1
            elif dominates(objectives[j], objectives[i]):
                dominates_set[j].append(i)
                dominated_by[i] += 1
    fronts = [[i for i in range(n) if dominated_by[i] == 0]]
    while fronts[-1]:
        nxt = []
        for i in fronts[-1]:
            for j in dominates_set[i]:
                dominated_by[j] -= 1
                if dominated_by[j] == 0:
                    nxt.append(j)
        fronts.append(sorted(nxt))
    return fronts[:-1]


def crowding_distance(objectives: Sequence[Sequence[float]],
                      front: Sequence[int]) -> Dict[int, float]:
    """Per-index crowding distance within one front.  Boundary points of
    every objective get +inf, so budget truncation keeps each axis's
    extreme (the max-qps, min-latency, max-recall corners) before
    filling in the middle."""
    dist = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: math.inf for i in front}
    n_obj = len(objectives[front[0]])
    for m in range(n_obj):
        order = sorted(front, key=lambda i: objectives[i][m])
        lo, hi = objectives[order[0]][m], objectives[order[-1]][m]
        dist[order[0]] = dist[order[-1]] = math.inf
        if hi == lo:
            continue
        for pos in range(1, len(order) - 1):
            gap = (objectives[order[pos + 1]][m]
                   - objectives[order[pos - 1]][m])
            dist[order[pos]] += gap / (hi - lo)
    return dist


def _rank_order(objectives: Sequence[Sequence[float]]) -> List[int]:
    """All indices, best-first: by front rank, then crowding distance
    (descending), ties by index — the NSGA-II survivor ordering."""
    order: List[int] = []
    for front in nondominated_sort(objectives):
        dist = crowding_distance(objectives, front)
        order.extend(sorted(front, key=lambda i: (-dist[i], i)))
    return order


def roofline_prune(configs: Sequence[ServingConfig], budget: int, *,
                   n_docs: int, dim: int, k: int,
                   repeat_fraction: float = 0.0,
                   ) -> Tuple[List[ServingConfig], int]:
    """Keep the ``budget`` best candidates by proxy Pareto rank +
    crowding; returns (kept, n_pruned).  Zero measurements happen here —
    this is the gate that keeps the measured population small."""
    if len(configs) <= budget:
        return list(configs), 0
    objs = [proxy_objectives(c, n_docs=n_docs, dim=dim, k=k,
                             repeat_fraction=repeat_fraction)
            for c in configs]
    keep = _rank_order(objs)[:budget]
    return [configs[i] for i in keep], len(configs) - budget


# ---------------------------------------------------------------------------
# Measurement under the real load generator.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeasuredPoint:
    """One load-tested genome: measured objectives + the endpoint
    identity that proves which path actually served."""

    config: ServingConfig
    qps: float
    p50_ms: float
    p99_ms: float
    recall: float
    identity: str
    corpus_dtype: Optional[str] = None

    def objectives(self) -> Tuple[float, float, float]:
        """Maximization vector: (qps, -p99_ms, recall)."""
        return (self.qps, -self.p99_ms, self.recall)

    def to_row(self) -> Dict[str, Any]:
        return {"config": self.config.to_dict(),
                "backend": self.config.backend,
                "identity": self.identity,
                "corpus_dtype": self.corpus_dtype,
                "qps": self.qps, "p50_ms": self.p50_ms,
                "p99_ms": self.p99_ms, "recall": self.recall}

    @classmethod
    def from_row(cls, row: Dict[str, Any]) -> "MeasuredPoint":
        return cls(config=ServingConfig.from_dict(row["config"]),
                   qps=row["qps"], p50_ms=row["p50_ms"],
                   p99_ms=row["p99_ms"], recall=row["recall"],
                   identity=row["identity"],
                   corpus_dtype=row.get("corpus_dtype"))


def pareto_front(points: Sequence[MeasuredPoint]) -> List[MeasuredPoint]:
    """The non-dominated subset of measured points, best-qps first."""
    objs = [p.objectives() for p in points]
    front = nondominated_sort(objs)[0] if points else []
    return [points[i] for i in sorted(front, key=lambda i: -objs[i][0])]


def _measure_once(cfg: ServingConfig, *, space, corpus, queries,
                  warmup_queries, workload, k: int, passes: int,
                  check_n: int):
    """One cold load test: fresh funnel + service, warm-up off the clock,
    ``passes`` replays of the workload (futures drained between passes so
    pass 2+ hits a warm cache — serve_bench's structural-win discipline),
    then a one-at-a-time spot-check retrieval.  Returns None when the
    config served nothing, else ``(qps, p50_ms, p99_ms, identity,
    corpus_dtype, got_indices)``."""
    from repro.core.pipeline import BruteForceGenerator, RetrievalPipeline
    from repro.serving.service import RetrievalService
    from repro.serving.sharded import ShardedPipeline

    backend = cfg.make_backend()
    if cfg.n_shards > 1:
        pipe = ShardedPipeline.from_corpus(space, corpus, cfg.n_shards,
                                           cand_qty=k, final_qty=k)
    else:
        pipe = RetrievalPipeline(BruteForceGenerator(space, corpus),
                                 cand_qty=k, final_qty=k)
    n_unique = int(queries.shape[0])
    try:
        svc = RetrievalService(cache_size=cfg.cache_size)
        svc.register_pipeline(
            "tuned", pipe, queries[0],
            batch_size=cfg.batch_size, max_wait_s=cfg.max_wait_s,
            max_queue=cfg.max_queue, overload=cfg.overload,
            backend=backend, corpus_dtype=cfg.corpus_dtype)
        with svc:
            # warm-up off the clock (compiles, index builds, tile tuning);
            # warm-up queries are outside the workload pool, submitted one
            # at a time so a small queue bound can't reject them
            n_warm = int(warmup_queries.shape[0])
            for i in range(min(cfg.batch_size, n_warm)):
                svc.retrieve([warmup_queries[i]], endpoint="tuned")
            svc.reset_stats()
            t0 = time.perf_counter()
            served = 0
            for _ in range(passes):
                futs = []
                for i in workload:
                    try:
                        futs.append(svc.submit(queries[int(i) % n_unique],
                                               endpoint="tuned"))
                    except ServiceOverloaded:
                        pass      # counted in the endpoint's rejected stat
                for f in futs:
                    try:
                        f.result()
                        served += 1
                    except ServiceOverloaded:
                        pass      # shed_oldest eviction
            wall = time.perf_counter() - t0
            snap = svc.snapshot()
            ep = snap.endpoints["tuned"]
            if served == 0 or ep.e2e.count == 0:
                return None
            # recall spot-check after the timing window, one request at a
            # time (stays under any admission bound)
            m = min(check_n, n_unique)
            got = np.stack([
                np.asarray(svc.retrieve([queries[i]],
                                        endpoint="tuned")[0].indices)
                for i in range(m)])
    finally:
        if hasattr(pipe, "close"):
            pipe.close()
    if not (ep.backend or "").startswith(cfg.backend):
        raise RuntimeError(
            f"config requested backend {cfg.backend!r} but the endpoint "
            f"served {ep.backend!r} — refusing to publish a fallback "
            f"measurement")
    if ep.corpus_dtype != cfg.corpus_dtype:
        raise RuntimeError(
            f"config requested corpus_dtype {cfg.corpus_dtype!r} but the "
            f"endpoint served {ep.corpus_dtype!r}")
    return (served / wall, ep.e2e.p50_ms, ep.e2e.p99_ms, ep.backend,
            ep.corpus_dtype, got)


def measure_config(cfg: ServingConfig, *, space, corpus, queries,
                   warmup_queries, workload, k: int, oracle_indices,
                   check_n: int = 16, passes: int = 2,
                   repeats: int = 1) -> Optional[MeasuredPoint]:
    """Load-test one genome under a real RetrievalService.

    Builds the genome's funnel (sharded when ``n_shards > 1``), registers
    it with the genome's backend instance / dtype / batching / admission
    knobs, replays the hot-set ``workload`` (indices into ``queries``)
    ``passes`` times per cold run — repeats within and across passes are
    what a cache can win on — then measures recall@k against
    ``oracle_indices`` on the first ``check_n`` queries (submitted one at
    a time, under the queue bound).

    ``repeats`` independent cold runs are aggregated by per-objective
    median, so a single scheduler hiccup can't mint or destroy a Pareto
    point; the published row is the genome's typical behavior.

    Returns None when the config served nothing in any repeat (e.g.
    every request rejected) — an unmeasurable point, not a Pareto
    candidate.  Raises if the endpoint snapshot shows a different
    backend/dtype than the genome declared: a silent capability fallback
    must never publish a measurement attributed to the requested path."""
    from repro.core.fusion import topk_recall

    samples = []
    for _ in range(max(repeats, 1)):
        sample = _measure_once(cfg, space=space, corpus=corpus,
                               queries=queries,
                               warmup_queries=warmup_queries,
                               workload=workload, k=k, passes=passes,
                               check_n=check_n)
        if sample is None:
            return None
        samples.append(sample)
    qps = float(np.median([s[0] for s in samples]))
    p50 = float(np.median([s[1] for s in samples]))
    p99 = float(np.median([s[2] for s in samples]))
    identity, corpus_dtype, got = samples[-1][3], samples[-1][4], samples[-1][5]
    m = got.shape[0]
    recall = float(topk_recall(np.asarray(oracle_indices)[:m], got))
    return MeasuredPoint(config=cfg, qps=qps, p50_ms=p50, p99_ms=p99,
                         recall=recall, identity=identity,
                         corpus_dtype=corpus_dtype)


# ---------------------------------------------------------------------------
# The evolution loop.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AutotuneResult:
    front: List[MeasuredPoint]
    archive: List[MeasuredPoint]
    counts: Dict[str, int]          # generated / measured / pruned


def autotune(measure_fn: Callable[[ServingConfig], Optional[MeasuredPoint]],
             *, k: int, n_docs: int, dim: int, seed: int = 0,
             generations: int = 3, population: int = 32,
             measure_budget: int = 8, repeat_fraction: float = 0.0,
             seed_points: Sequence[MeasuredPoint] = (),
             explore_configs: Sequence[ServingConfig] = (),
             space=None, corpus=None,
             log: Optional[Callable[[str], None]] = None) -> AutotuneResult:
    """Evolve the measured latency/throughput/recall Pareto front.

    Per generation: generate ``population`` unique legal candidates
    (generation 0 from ``explore_configs`` + uniform sampling; later from
    crossover + mutation of front-ranked archive parents), prune them to
    ``measure_budget`` by the zero-cost roofline proxy
    (:func:`roofline_prune`), measure the survivors with ``measure_fn``,
    and fold them into the archive.  ``seed_points`` (e.g. the
    hand-picked serve_bench grid, already measured) initialize the
    archive so the front can only ever improve on the grid.

    Deterministic in ``seed`` for a deterministic ``measure_fn`` — every
    random draw flows from one ``np.random.default_rng(seed)``."""
    rng = np.random.default_rng(seed)
    archive: List[MeasuredPoint] = list(seed_points)
    seen = {p.config.key() for p in archive}
    generated = len(archive)
    measured = len(archive)
    pruned = 0
    for gen in range(generations):
        pool: List[ServingConfig] = []
        ranked: List[MeasuredPoint] = []
        if archive:
            objs = [p.objectives() for p in archive]
            ranked = [archive[i] for i in _rank_order(objs)]
        if gen == 0:
            for cfg in explore_configs:
                if (check_config(cfg, k, space, corpus) is None
                        and cfg.key() not in seen):
                    seen.add(cfg.key())
                    pool.append(cfg)
        tries = 0
        while len(pool) < population and tries < population * 40:
            tries += 1
            if gen == 0 or not ranked or rng.random() < 0.25:
                cand = random_config(rng, k)
            else:
                # tournament-of-ranked parents: earlier archive rows are
                # better (front rank, then crowding)
                half = max(1, len(ranked) // 2)
                pa = ranked[int(rng.integers(half))]
                pb = ranked[int(rng.integers(len(ranked)))]
                cand = mutate(crossover(pa.config, pb.config, rng, k),
                              rng, k)
            if check_config(cand, k, space, corpus) is not None:
                continue
            if cand.key() in seen:
                continue
            seen.add(cand.key())
            pool.append(cand)
        generated += len(pool)
        kept, n_pruned = roofline_prune(
            pool, measure_budget, n_docs=n_docs, dim=dim, k=k,
            repeat_fraction=repeat_fraction)
        pruned += n_pruned
        if log:
            log(f"gen {gen}: {len(pool)} candidates, "
                f"{n_pruned} proxy-pruned, measuring {len(kept)}")
        for cfg in kept:
            point = measure_fn(cfg)
            measured += 1
            if point is not None:
                archive.append(point)
    front = pareto_front(archive)
    counts = {"generated": generated, "measured": measured,
              "pruned": pruned}
    assert counts["pruned"] + counts["measured"] == counts["generated"]
    return AutotuneResult(front=front, archive=archive, counts=counts)


# ---------------------------------------------------------------------------
# Tuned profiles: a front row the service accepts at registration.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TunedProfile:
    """A serializable Pareto-front row: the genome plus its measured
    objectives and the identity string of the path that produced them.

    ``RetrievalService.register_pipeline(profile=...)`` /
    ``register_runner(profile=...)`` rebind backend, corpus dtype and
    batching/admission knobs from the profile in one shot; the profile's
    ``tag`` lands in :class:`~repro.serving.stats.EndpointSnapshot` and
    the endpoint's cache keys (provenance — a tuned endpoint's entries
    never alias a hand-configured one's).  ``cache_size`` is a
    *service*-level knob: pass ``profile.config.cache_size`` to the
    ``RetrievalService`` constructor."""

    config: ServingConfig
    qps: float = 0.0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    recall: float = 1.0
    identity: str = ""
    source: str = "autotune"

    @property
    def tag(self) -> str:
        """Short stable digest of the genome — the provenance string."""
        payload = json.dumps(self.config.to_dict(), sort_keys=True,
                             separators=(",", ":"))
        digest = hashlib.blake2b(payload.encode(),
                                 digest_size=6).hexdigest()
        return f"profile:{digest}"

    @classmethod
    def from_point(cls, point: MeasuredPoint,
                   source: str = "autotune") -> "TunedProfile":
        return cls(config=point.config, qps=point.qps, p50_ms=point.p50_ms,
                   p99_ms=point.p99_ms, recall=point.recall,
                   identity=point.identity, source=source)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["tag"] = self.tag
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TunedProfile":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in fields}
        kw["config"] = ServingConfig.from_dict(d["config"])
        return cls(**kw)

    def to_spec(self):
        """This profile as a consolidated
        :class:`~repro.serving.spec.EndpointSpec`: the registration-time
        expansion of a tuned row (backend instance, corpus dtype,
        batching/admission knobs, and — for funnel genomes — the
        ``rerank_keep`` width and rerank stage budget), with the profile
        itself carried for provenance.  ``config.cache_size`` remains a
        service-level knob."""
        from repro.serving.funnel import StageBudget
        from repro.serving.spec import EndpointSpec

        cfg = self.config
        budget = (StageBudget(rerank_s=cfg.rerank_budget_ms / 1e3)
                  if cfg.rerank_budget_ms is not None else None)
        return EndpointSpec(
            batch_size=cfg.batch_size, max_wait_s=cfg.max_wait_s,
            max_queue=cfg.max_queue, overload=cfg.overload,
            backend=cfg.make_backend(), corpus_dtype=cfg.corpus_dtype,
            profile=self, budget=budget, rerank_keep=cfg.rerank_keep)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "TunedProfile":
        return cls.from_dict(json.loads(text))
