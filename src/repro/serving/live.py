"""Live corpora: insert/delete/upsert behind a serving endpoint.

``LiveCorpus`` wraps the pure segment algebra in ``core.segments`` with
everything serving needs: mutation ordering under a writer lock, an
atomically epoch-swapped immutable snapshot (readers pin a snapshot
reference once per batch and finish on it — a Python attribute read, so
the swap is atomic and a query can never observe a half-applied
mutation batch), a background compactor thread that materializes
main ⊕ append ⊖ tombstones and rebuilds/warms the main ANN index
*off-thread* before swapping it in, and the freshness metrics
(`segment counts, tombstone count, compaction latency, snapshot age``)
that surface in ``EndpointSnapshot``.

Concurrency model
-----------------
- **Writers** (``insert`` / ``delete`` / ``upsert``) serialize on one
  lock; each batch builds a complete new ``SegmentSnapshot`` with
  ``generation + 1`` and swaps it in one assignment.
- **Readers** call :meth:`snapshot` (or go through ``LiveGenerator``,
  which pins a snapshot per batch via ``bind_snapshot``) and never
  block writers.
- **The compactor** races both: it captures a snapshot + per-id version
  vector, does the expensive materialization and ANN-index warm outside
  the lock, then re-enters the lock to reconcile mutations that landed
  meanwhile (rows upserted/deleted since are tombstoned in the new
  main; rows appended since become the new append tail) and swaps.
  Generations stay strictly monotone throughout.

Stale cache hits are structurally impossible because the serving layer
length-frames the snapshot generation into every cache key
(``QueryCache.key(..., generation=...)``) — see ``RetrievalService``.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import segments
from repro.core.backends import (PallasBackend, ReferenceBackend,
                                 StreamingBackend, backend_identity,
                                 invalidate_ann_index_entries,
                                 resolve_backend)
from repro.core.brute_force import TopK
from repro.core.segments import SegmentSnapshot
from repro.core.spaces import canonical_dtype, cast_corpus, corpus_dtype

__all__ = ["LiveCorpus", "LiveGenerator", "SnapshotGenerator"]

_EXACT_BACKENDS = (ReferenceBackend, StreamingBackend, PallasBackend)


class LiveCorpus:
    """A mutable corpus served through generation-versioned segments.

    ``backend`` serves the frozen main segment (any registered backend,
    including ``graph_ann``/``napp`` — their lazily built indexes are
    keyed by the main corpus object, which only changes at compaction,
    so the index stays warm across non-compacting mutations).
    ``append_backend`` scans the append segment and must be exact
    (reference / streaming / pallas).

    ``max_append`` / ``max_dead`` bound the append segment and the
    tombstone count: crossing either threshold triggers compaction —
    handed to the background compactor thread when :meth:`start` has
    been called, run inline on the mutating thread otherwise.  Bounded
    tombstones also bound the extra fetch depth ``live_topk`` needs
    (``k + tombstones(segment)``), which is what keeps ANN budgets
    (``ef``) sufficient under churn.
    """

    def __init__(self, space, corpus=None, *, ids=None,
                 backend: Any = "reference",
                 append_backend: Any = "reference",
                 corpus_dtype: Optional[str] = None,
                 max_append: int = 1024,
                 max_dead: Optional[int] = None,
                 compact_interval_s: Optional[float] = None,
                 time_fn: Callable[[], float] = time.monotonic):
        self.space = space
        self._time = time_fn
        self.max_append = int(max_append)
        self.max_dead = None if max_dead is None else int(max_dead)
        self.compact_interval_s = compact_interval_s

        self._dtype = (canonical_dtype(corpus_dtype)
                       if corpus_dtype is not None else None)
        if corpus is not None and self._dtype is not None:
            corpus = cast_corpus(corpus, self._dtype)

        self.main_backend = (resolve_backend(backend, space, corpus)
                             if corpus is not None
                             else resolve_backend(backend))
        self.append_backend = resolve_backend(append_backend)
        if not isinstance(self.append_backend, _EXACT_BACKENDS):
            raise ValueError(
                "append_backend must be exact (reference/streaming/pallas): "
                "the append segment is scanned, not indexed — got "
                f"{backend_identity(self.append_backend)!r}")

        n = 0
        if corpus is not None:
            corpus = jax.tree.map(jnp.asarray, corpus)
            n = segments._rows(corpus)
            if n is None:
                raise ValueError("corpus is not a row-major pytree")
        if ids is None:
            ids = np.arange(n, dtype=np.int64)
        else:
            ids = np.asarray(ids, dtype=np.int64)
            if len(ids) != n or len(np.unique(ids)) != n:
                raise ValueError("ids must be unique and match the corpus "
                                 "row count")
        self._lock = threading.RLock()
        self._compact_lock = threading.Lock()
        self._snapshot = SegmentSnapshot(
            generation=0, main=corpus, main_ids=ids,
            main_dead=np.zeros(n, dtype=bool))
        self._loc: Dict[int, Tuple[str, int]] = {
            int(i): ("main", row) for row, i in enumerate(ids)}
        self._versions: Dict[int, int] = {int(i): 0 for i in ids}
        self._next_id = int(ids.max()) + 1 if n else 0
        self._swapped_at = self._time()
        self._compactions = 0
        self._compaction_s: collections.deque = collections.deque(maxlen=128)
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- reading ------------------------------------------------------------
    def snapshot(self) -> SegmentSnapshot:
        """The current immutable state.  Hold the reference for the whole
        batch: everything computed from one snapshot is mutually
        consistent and survives any number of concurrent swaps."""
        return self._snapshot

    @property
    def generation(self) -> int:
        return self._snapshot.generation

    @property
    def corpus_dtype(self) -> Optional[str]:
        if self._dtype is not None:
            return self._dtype
        snap = self._snapshot
        return corpus_dtype(snap.main if snap.main is not None
                            else snap.append)

    def topk(self, queries, k: int) -> TopK:
        """Search the current snapshot (logical ids; see
        ``segments.live_topk``)."""
        return segments.live_topk(
            self.space, self.snapshot(), queries, k,
            main_backend=self.main_backend,
            append_backend=self.append_backend)

    def live_stats(self) -> Dict[str, Any]:
        """Freshness metrics for ``EndpointSnapshot``."""
        snap = self._snapshot
        return {
            "generation": snap.generation,
            "segment_rows": {"main": snap.n_main, "append": snap.n_append},
            "tombstones": snap.n_dead,
            "snapshot_age_s": self._time() - self._swapped_at,
            "compactions": self._compactions,
            "compaction_s": list(self._compaction_s),
        }

    # -- mutation -----------------------------------------------------------
    def _swap(self, snap: SegmentSnapshot):
        # caller holds self._lock
        self._snapshot = snap
        self._swapped_at = self._time()

    def _coerce_rows(self, rows):
        rows = jax.tree.map(jnp.asarray, rows)
        m = segments._rows(rows)
        if not m:
            raise ValueError("rows must be a row-major pytree with at "
                             "least one row")
        if self._dtype is None:
            self._dtype = corpus_dtype(rows)
        elif corpus_dtype(rows) != self._dtype:
            rows = cast_corpus(rows, self._dtype)
        return rows, m

    def insert(self, rows) -> np.ndarray:
        """Append ``rows`` (a row-major pytree) as new documents; returns
        their newly assigned logical ids."""
        rows, m = self._coerce_rows(rows)
        with self._lock:
            snap = self._snapshot
            new_ids = np.arange(self._next_id, self._next_id + m,
                                dtype=np.int64)
            self._next_id += m
            base = snap.n_append
            self._swap(SegmentSnapshot(
                generation=snap.generation + 1,
                main=snap.main, main_ids=snap.main_ids,
                main_dead=snap.main_dead,
                append=segments.concat_rows(snap.append, rows),
                append_ids=np.concatenate([snap.append_ids, new_ids]),
                append_dead=np.concatenate(
                    [snap.append_dead, np.zeros(m, dtype=bool)])))
            for j, i in enumerate(new_ids):
                ii = int(i)
                self._loc[ii] = ("append", base + j)
                self._versions[ii] = self._versions.get(ii, -1) + 1
        self._maybe_compact()
        return new_ids

    def delete(self, ids) -> int:
        """Tombstone the given logical ids.  Raises ``KeyError`` on an id
        that is not live.  Returns the number of rows tombstoned."""
        ids = [int(i) for i in np.atleast_1d(np.asarray(ids, dtype=np.int64))]
        with self._lock:
            snap = self._snapshot
            for i in ids:
                if i not in self._loc:
                    raise KeyError(f"id {i} is not live")
            main_dead = snap.main_dead.copy()
            append_dead = snap.append_dead.copy()
            for i in ids:
                seg, pos = self._loc.pop(i)
                (main_dead if seg == "main" else append_dead)[pos] = True
                self._versions[i] += 1
            self._swap(dataclasses.replace(
                snap, generation=snap.generation + 1,
                main_dead=main_dead, append_dead=append_dead))
        self._maybe_compact()
        return len(ids)

    def upsert(self, ids, rows) -> np.ndarray:
        """Insert-or-replace: each ``(id, row)`` pair replaces the live
        row for that logical id (tombstoning the superseded physical
        row) or inserts a fresh document under that id.  Logical ids are
        stable across upserts and epochs."""
        rows, m = self._coerce_rows(rows)
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        if len(ids) != m:
            raise ValueError(f"{len(ids)} ids for {m} rows")
        with self._lock:
            snap = self._snapshot
            main_dead = snap.main_dead.copy()
            append_dead = snap.append_dead.copy()
            base = snap.n_append
            new_dead = np.zeros(m, dtype=bool)
            for j, i in enumerate(ids):
                ii = int(i)
                old = self._loc.get(ii)
                if old is not None:
                    seg, pos = old
                    if seg == "main":
                        main_dead[pos] = True
                    elif pos < base:
                        append_dead[pos] = True
                    else:           # superseded earlier in this same batch
                        new_dead[pos - base] = True
                self._loc[ii] = ("append", base + j)
                self._versions[ii] = self._versions.get(ii, -1) + 1
                self._next_id = max(self._next_id, ii + 1)
            self._swap(SegmentSnapshot(
                generation=snap.generation + 1,
                main=snap.main, main_ids=snap.main_ids,
                main_dead=main_dead,
                append=segments.concat_rows(snap.append, rows),
                append_ids=np.concatenate([snap.append_ids, ids]),
                append_dead=np.concatenate([append_dead, new_dead])))
        self._maybe_compact()
        return ids

    # -- compaction ---------------------------------------------------------
    def _maybe_compact(self):
        snap = self._snapshot
        over = (snap.n_append >= self.max_append
                or (self.max_dead is not None
                    and snap.n_dead >= self.max_dead))
        if not over:
            return
        if self._thread is not None and self._thread.is_alive():
            self._wake.set()
        else:
            self.compact()

    def compact(self) -> bool:
        """Materialize main ⊕ append ⊖ tombstones into a fresh main
        segment and epoch-swap it in.  The expensive part (row
        gather/concat + warming the main ANN index) runs outside the
        writer lock; mutations that land meanwhile are reconciled at
        swap time (their superseded rows tombstoned in the new main,
        their new rows carried over as the append tail).  Returns False
        when there was nothing to compact."""
        with self._compact_lock:
            t0 = self._time()
            with self._lock:
                snap0 = self._snapshot
                if snap0.n_append == 0 and snap0.n_dead == 0:
                    return False
                vers0 = {int(i): self._versions[int(i)]
                         for i in snap0.live_ids()}
            corpus, ids = segments.materialize(snap0)
            if corpus is not None and hasattr(self.main_backend, "_index"):
                # warm the lazily built ANN index off-thread so the epoch
                # swap lands with the new main immediately servable
                self.main_backend._index(self.space, corpus, len(ids))
            with self._lock:
                cur = self._snapshot
                main_dead = np.fromiter(
                    (int(i) not in self._loc
                     or self._versions[int(i)] != vers0[int(i)]
                     for i in ids), dtype=bool, count=len(ids))
                tail_lo = snap0.n_append
                tail_ids = cur.append_ids[tail_lo:]
                tail_dead = cur.append_dead[tail_lo:]
                tail = (None if not len(tail_ids) else jax.tree.map(
                    lambda x: x[tail_lo:], cur.append))
                self._swap(SegmentSnapshot(
                    generation=cur.generation + 1,
                    main=corpus, main_ids=ids, main_dead=main_dead,
                    append=tail, append_ids=tail_ids,
                    append_dead=tail_dead))
                for key, (seg, pos) in list(self._loc.items()):
                    if seg == "append" and pos >= tail_lo:
                        self._loc[key] = ("append", pos - tail_lo)
                for row, i in enumerate(ids):
                    if not main_dead[row]:
                        self._loc[int(i)] = ("main", row)
                retired = snap0.main
            self._compactions += 1
            self._compaction_s.append(self._time() - t0)
            # targeted invalidation: only the retired main's index
            # entries — other corpora's (other endpoints') entries and
            # in-flight builds are untouched.  In-flight batches pinning
            # the old snapshot still hold the corpus+index alive.
            if retired is not None and retired is not corpus:
                invalidate_ann_index_entries(retired)
            return True

    # -- background compactor / lifecycle -----------------------------------
    def start(self) -> "LiveCorpus":
        """Start the background compactor thread (idempotent).  It wakes
        on threshold triggers and every ``compact_interval_s`` (if
        set)."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._compactor_loop, name="live-compactor",
                daemon=True)
            self._thread.start()
        return self

    def _compactor_loop(self):
        while not self._stop.is_set():
            self._wake.wait(timeout=self.compact_interval_s)
            self._wake.clear()
            if self._stop.is_set():
                return
            snap = self._snapshot
            if snap.n_append or snap.n_dead:
                self.compact()

    def close(self):
        """Stop the compactor thread and wait for any in-flight
        compaction to finish (the corpus stays queryable)."""
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "LiveCorpus":
        return self

    def __exit__(self, *exc):
        self.close()


@dataclasses.dataclass(frozen=True)
class SnapshotGenerator:
    """A ``CandidateGenerator`` frozen at one snapshot: everything the
    batch computes comes from a single consistent logical state."""

    live: LiveCorpus
    snap: SegmentSnapshot

    def generate(self, query_repr, k: int) -> TopK:
        return segments.live_topk(
            self.live.space, self.snap, query_repr, k,
            main_backend=self.live.main_backend,
            append_backend=self.live.append_backend)


class LiveGenerator:
    """Candidate generator over a :class:`LiveCorpus`.

    ``RetrievalPipeline.run`` / ``ShardedPipeline.generate`` call
    :meth:`bind_snapshot` once per batch, so an in-flight batch finishes
    on the snapshot it started with regardless of concurrent mutations
    or compactions.  ``last_served_generation`` records the pinned
    generation; the batcher worker reads it right after the batch to
    stamp cache keys (single-threaded per endpoint, so the read is
    race-free)."""

    def __init__(self, live: LiveCorpus):
        self.live = live
        self.last_served_generation: Optional[int] = None

    @property
    def backend(self):
        return self.live.main_backend

    @property
    def corpus_dtype(self) -> Optional[str]:
        return self.live.corpus_dtype

    def bind_snapshot(self) -> SnapshotGenerator:
        snap = self.live.snapshot()
        self.last_served_generation = snap.generation
        return SnapshotGenerator(self.live, snap)

    def generate(self, query_repr, k: int) -> TopK:
        return self.bind_snapshot().generate(query_repr, k)
