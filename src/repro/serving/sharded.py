"""Sharded-corpus serving: K corpus shards behind one batcher endpoint.

NMSLIB scales its query server by splitting the collection across servers
and merging per-server result lists; this module is that idea inside one
process (and, with a mesh, across devices):

  * :func:`shard_corpus` partitions any row-major corpus pytree (dense
    ``[N, D]`` arrays, ``SparseVectors``, ``FusedVectors``) into K
    *contiguous row ranges*.  With a :class:`~repro.distributed.sharding.
    ParallelCtx` carrying a mesh, each shard is ``device_put`` onto a mesh
    device along the mapped axis; otherwise shards stay host-resident and
    are searched host-parallel (one thread per shard — JAX ops release the
    GIL while executing).
  * :class:`ShardedPipeline` runs one candidate generator per shard (exact
    brute force by default; graph-ANN or NAPP via ``generator_factory``),
    rebases local row ids by the shard offset, merges the K candidate
    lists with :func:`~repro.core.brute_force.merge_topk`, and applies the
    usual reranker tail once over the merged global candidates.  The
    per-shard execution path is pluggable: ``from_corpus(...,
    backend=...)`` / :meth:`ShardedPipeline.with_backend` resolve a
    :mod:`repro.core.backends` backend against each shard's slice.

Bit-identity: contiguous shards concatenated in row order preserve
``lax.top_k``'s tie-break (lower slot == lower global row id), and every
per-row score is computed from exactly the same values as the unsharded
scan — so for exact generators the sharded result equals the unsharded
``RetrievalPipeline.run`` bit for bit (verified in
``tests/test_sharded.py``).

A ``ShardedPipeline`` exposes ``run(query_repr, q_tokens)`` and
``generate(query_repr, k)``, so it registers behind a single
:class:`~repro.serving.batcher.ContinuousBatcher` endpoint via
``RetrievalService.register_pipeline`` — the router, cache, and stats
layers never learn the corpus is sharded — and also slots into a larger
:class:`~repro.core.pipeline.RetrievalPipeline` as a candidate generator.
"""

from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Optional, Tuple

import jax

from repro.core.backends import resolve_backend
from repro.core.brute_force import TopK, concat_topk, merge_topk
from repro.core.pipeline import (BruteForceGenerator, apply_rerankers,
                                 pin_snapshot)
from repro.core.spaces import canonical_dtype, cast_corpus

__all__ = ["CorpusShard", "shard_corpus", "ShardedPipeline"]


@dataclasses.dataclass(frozen=True)
class CorpusShard:
    """One contiguous row range of the corpus: local rows ``[0, n_rows)``
    correspond to global rows ``[offset, offset + n_rows)``."""

    corpus: Any
    offset: int
    n_rows: int


def _corpus_rows(corpus) -> int:
    return jax.tree.leaves(corpus)[0].shape[0]


def _placement_devices(ctx, axis: str):
    """One device per shard slot along the mapped mesh axis (flat mesh
    order when the logical axis resolves to nothing)."""
    if ctx is None or getattr(ctx, "mesh", None) is None:
        return None
    mesh = ctx.mesh
    phys = ctx.mesh_axes(axis)
    if phys is None:
        return list(mesh.devices.flat)
    names = (phys,) if isinstance(phys, str) else list(phys)
    order = [mesh.axis_names.index(a) for a in names]
    rest = [i for i in range(mesh.devices.ndim) if i not in order]
    moved = mesh.devices.transpose(order + rest)
    # first device of each slice along the corpus axis/axes
    n_slots = 1
    for a in names:
        n_slots *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return list(moved.reshape(n_slots, -1)[:, 0])


def shard_corpus(corpus, n_shards: int, *, ctx=None,
                 axis: str = "corpus") -> Tuple[CorpusShard, ...]:
    """Partition a corpus pytree into ``n_shards`` contiguous row ranges.

    Row order across shards equals global row order — load-bearing for the
    bit-identical merge (see module docstring).  ``ctx`` (a ParallelCtx)
    device-places shard ``i`` on the ``i % n_devices``-th device along the
    mesh axis that logical ``axis`` maps to; without a mesh the slices stay
    wherever the corpus lives.
    """
    n = _corpus_rows(corpus)
    if not 1 <= n_shards <= n:
        raise ValueError(f"n_shards={n_shards} must be in [1, {n}]")
    devices = _placement_devices(ctx, axis)
    bounds = [n * i // n_shards for i in range(n_shards + 1)]
    shards = []
    for i, (lo, hi) in enumerate(zip(bounds, bounds[1:])):
        piece = jax.tree.map(lambda x: x[lo:hi], corpus)
        if devices is not None:
            piece = jax.device_put(piece, devices[i % len(devices)])
        shards.append(CorpusShard(piece, lo, hi - lo))
    return tuple(shards)


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedPipeline:
    """Drop-in for ``RetrievalPipeline.run`` over a K-way sharded corpus.

    Each shard's generator sees only its slice (local row ids); offsets
    rebase to global ids, ``merge_topk`` folds the K lists into the global
    top-``cand_qty``, and the rerankers run once on the merged candidates.
    Build with :meth:`from_corpus`.
    """

    shards: Tuple[CorpusShard, ...]
    generators: Tuple[Any, ...]
    intermediate: Optional[Any] = None
    final: Optional[Any] = None
    cand_qty: int = 100
    interm_qty: int = 50
    final_qty: int = 10
    executor: Optional[ThreadPoolExecutor] = None

    @classmethod
    def from_corpus(
        cls, space, corpus, n_shards: int, *, ctx=None, axis: str = "corpus",
        generator_factory: Optional[Callable[[CorpusShard], Any]] = None,
        backend=None, corpus_dtype: Optional[str] = None,
        intermediate=None, final=None,
        cand_qty: int = 100, interm_qty: int = 50, final_qty: int = 10,
        host_parallel: bool = True,
    ) -> "ShardedPipeline":
        """Shard ``corpus`` K ways and build one generator per shard.

        ``generator_factory(shard) -> CandidateGenerator`` defaults to exact
        ``BruteForceGenerator(space, shard.corpus)``; pass a factory building
        per-shard ``GraphANNGenerator`` / ``NappGenerator`` for approximate
        search (merged results are then the union-of-shards approximation,
        not bit-identical to a global index).

        ``backend`` selects the execution path of the default per-shard
        generators (a :mod:`repro.core.backends` name, ``"auto"``, or
        instance), resolved per shard against that shard's slice — a
        backend that cannot serve the space falls back to reference shard
        by shard.  Mutually exclusive with ``generator_factory`` (a custom
        factory owns its generators' execution entirely).

        ``corpus_dtype`` casts the corpus to a residency dtype *before*
        sharding (``"bfloat16"`` halves every shard's footprint; scores
        stay f32 — the precision contract in ``core.spaces``).  Casting
        commutes with row-slicing, so a bf16 sharded pipeline stays
        bit-identical to the unsharded bf16 scan.
        """
        if backend is not None and generator_factory is not None:
            raise ValueError(
                "pass either backend= or generator_factory=, not both: a "
                "custom factory owns its generators' execution path")
        if corpus_dtype is not None:
            corpus = cast_corpus(corpus, canonical_dtype(corpus_dtype))
        shards = shard_corpus(corpus, n_shards, ctx=ctx, axis=axis)
        if generator_factory is None:
            def generator_factory(shard: CorpusShard):
                resolved = (None if backend is None else
                            resolve_backend(backend, space, shard.corpus))
                return BruteForceGenerator(space, shard.corpus,
                                           backend=resolved)
        executor = (ThreadPoolExecutor(max_workers=n_shards,
                                       thread_name_prefix="shard")
                    if host_parallel and n_shards > 1 else None)
        return cls(shards=shards,
                   generators=tuple(generator_factory(s) for s in shards),
                   intermediate=intermediate, final=final, cand_qty=cand_qty,
                   interm_qty=interm_qty, final_qty=final_qty,
                   executor=executor)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def corpus_dtype(self) -> Optional[str]:
        """The shards' common corpus residency dtype (None when the
        per-shard generators disagree or carry no dtype seam)."""
        dts = {getattr(g, "corpus_dtype", None) for g in self.generators}
        if len(dts) == 1 and (d := dts.pop()) is not None:
            return d
        return None

    def with_corpus_dtype(self, dtype) -> "ShardedPipeline":
        """Same shards, different corpus residency dtype: every per-shard
        generator is recast (casting commutes with the row-slicing that
        built the shards, so merged results equal an unsharded cast
        corpus bit for bit).  The rebound pipeline owns a fresh
        host-parallel pool — close it separately.  Raises TypeError when
        a shard generator has no dtype seam (e.g. per-shard graph-ANN)."""
        for g in self.generators:
            if not hasattr(g, "with_corpus_dtype"):
                raise TypeError(
                    f"shard generator {type(g).__name__} does not take a "
                    "corpus residency dtype")
        generators = tuple(g.with_corpus_dtype(dtype)
                           for g in self.generators)
        shards = tuple(
            dataclasses.replace(s, corpus=getattr(g, "corpus", s.corpus))
            for s, g in zip(self.shards, generators))
        executor = (ThreadPoolExecutor(max_workers=self.n_shards,
                                       thread_name_prefix="shard")
                    if self.executor is not None else None)
        return dataclasses.replace(self, shards=shards,
                                   generators=generators, executor=executor)

    def with_backend(self, backend) -> "ShardedPipeline":
        """Same shards, different execution path: every per-shard generator
        is rebound onto ``backend`` (resolved against its own slice, so an
        incapable backend falls back to reference shard by shard).  The
        rebound pipeline owns a fresh host-parallel pool — close it
        separately.  Raises TypeError when a shard generator has no
        backend seam (e.g. per-shard graph-ANN)."""
        for g in self.generators:
            if not hasattr(g, "with_backend"):
                raise TypeError(
                    f"shard generator {type(g).__name__} does not take an "
                    "execution backend")
        executor = (ThreadPoolExecutor(max_workers=self.n_shards,
                                       thread_name_prefix="shard")
                    if self.executor is not None else None)
        return dataclasses.replace(
            self,
            generators=tuple(g.with_backend(backend)
                             for g in self.generators),
            executor=executor)

    # CandidateGenerator protocol: a ShardedPipeline can itself feed a
    # larger RetrievalPipeline as its (sharded) candidate stage.
    def generate(self, query_repr, k: Optional[int] = None) -> TopK:
        """Global top-k candidates from the sharded generator stage."""
        k = self.cand_qty if k is None else k
        # Live-corpus shard generators are pinned up front, before the
        # fan-out, so one batch sees a mutually consistent set of
        # per-shard states even while writers and compactors race the
        # query threads (the pin_snapshot seam shared with
        # RetrievalPipeline and the serving funnel).
        generators = [pin_snapshot(g) for g in self.generators]

        def one(gen, shard: CorpusShard) -> TopK:
            local = gen.generate(query_repr, min(k, shard.n_rows))
            return TopK(local.scores, local.indices + shard.offset)

        # under a jit trace the queries are tracers, which must not cross
        # thread boundaries (UnexpectedTracerError) — the traced program is
        # "parallel" shard-by-shard in the compiled graph anyway
        tracing = any(isinstance(leaf, jax.core.Tracer)
                      for leaf in jax.tree.leaves(query_repr))
        if self.executor is not None and not tracing:
            parts = list(self.executor.map(one, generators, self.shards))
        else:
            parts = [one(g, s) for g, s in zip(generators, self.shards)]
        cat = concat_topk(parts)
        return merge_topk(cat, min(k, cat.scores.shape[1]))

    def run(self, query_repr, q_tokens=None) -> TopK:
        cands = self.generate(query_repr, self.cand_qty)
        return apply_rerankers(
            cands, q_tokens, intermediate=self.intermediate, final=self.final,
            interm_qty=self.interm_qty, final_qty=self.final_qty)

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        """Shut down the host-parallel worker pool (no-op when serial).
        Long-lived processes that rebuild pipelines (index refresh, shard
        sweeps) should close retired ones; ``run`` after close falls back
        to serial execution."""
        if self.executor is not None:
            self.executor.shutdown(wait=True)
            object.__setattr__(self, "executor", None)

    def __enter__(self) -> "ShardedPipeline":
        return self

    def __exit__(self, *exc):
        self.close()
