"""Query-result LRU cache keyed on a quantized query representation.

A hit skips the whole funnel: the stored per-query result (numpy pytree,
exactly as a batcher produced it) is returned immediately, so cached
answers are bit-identical to freshly-served ones by construction.

Keys quantize the query representation (round to ``decimals``) before
hashing so that float jitter below the quantization step — e.g. the same
query re-encoded on a different host — still hits.  The endpoint name
AND the endpoint's execution-backend identity AND its corpus residency
dtype (the precision tier — f32 vs bf16) are part of the key: the
same vector against the dense and the fused space is two different
questions, and two endpoints over the same corpus that differ only in
``backend=`` must never alias each other's entries (backends are exact
and parity-tested, but a cache that *assumes* that would mask any future
divergence instead of surfacing it).  All key fields are length-framed
before hashing, so no (endpoint, backend) pair can collide with another
by sliding bytes across field boundaries.

The cache sits *above* admission control: a hit never touches the
endpoint's queue, so hot queries keep being answered even while the
endpoint is saturated and rejecting or shedding new work.
"""

from __future__ import annotations

import collections
import hashlib
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["quantized_key", "QueryCache"]


def _framed(h, data: bytes):
    """Length-prefix a variable-size field so adjacent fields can't alias."""
    h.update(len(data).to_bytes(8, "little"))
    h.update(data)


def quantized_key(endpoint: str, query: Any, decimals: int = 6,
                  backend: Optional[str] = None,
                  corpus_dtype: Optional[str] = None,
                  profile: Optional[str] = None,
                  generation: Optional[int] = None) -> bytes:
    """Stable digest of (endpoint, backend identity, corpus residency
    dtype, tuned-profile tag, corpus generation, quantized query).

    Float leaves are rounded to ``decimals``; integer leaves (token ids,
    sparse indices) are hashed exactly.  Leaf shapes and dtypes are folded
    in so e.g. f32[8] and f32[2,4] with equal bytes cannot collide.
    ``corpus_dtype`` is keyed exactly like ``backend``: a bf16 endpoint's
    scores are a different precision tier than an f32 endpoint's over the
    same corpus, and the two must never answer from each other's
    entries.  ``profile`` (a ``TunedProfile.tag``) keys autotuned
    endpoints' entries by provenance the same way.  ``generation`` is the
    live-corpus snapshot generation (``repro.serving.live``): results are
    stored under the generation that actually produced them and looked up
    under the current one, so a stale hit after a mutation or compaction
    is structurally impossible — the key differs.  Frozen endpoints pass
    None, which frames as the empty field (distinct from generation 0)."""
    h = hashlib.blake2b(digest_size=16)
    _framed(h, endpoint.encode())
    _framed(h, (backend or "").encode())
    _framed(h, (corpus_dtype or "").encode())
    _framed(h, (profile or "").encode())
    _framed(h, b"" if generation is None else str(int(generation)).encode())
    for leaf in jax.tree.leaves(query):
        a = np.asarray(leaf)
        if np.issubdtype(a.dtype, np.floating):
            # + 0.0 normalises -0.0 to +0.0 (their bytes differ); jitter
            # crossing a rounding boundary still misses — inherent to
            # quantization, a perf loss only, never a wrong result
            a = np.round(a.astype(np.float64), decimals) + 0.0
        _framed(h, str(a.dtype).encode())
        _framed(h, np.asarray(a.shape, np.int64).tobytes())
        _framed(h, np.ascontiguousarray(a).tobytes())
    return h.digest()


class QueryCache:
    """Thread-safe LRU over quantized-query keys."""

    def __init__(self, capacity: int = 4096, decimals: int = 6):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.decimals = decimals
        self._lock = threading.Lock()
        self._data: "collections.OrderedDict[bytes, Any]" = collections.OrderedDict()

    def key(self, endpoint: str, query: Any,
            backend: Optional[str] = None,
            corpus_dtype: Optional[str] = None,
            profile: Optional[str] = None,
            generation: Optional[int] = None) -> bytes:
        return quantized_key(endpoint, query, self.decimals,
                             backend=backend, corpus_dtype=corpus_dtype,
                             profile=profile, generation=generation)

    def get(self, key: bytes) -> Optional[Any]:
        with self._lock:
            if key not in self._data:
                return None
            self._data.move_to_end(key)
            return self._data[key]

    def put(self, key: bytes, value: Any):
        # freeze array leaves: hits hand out the stored pytree by
        # reference, so an in-place mutation by one client would silently
        # corrupt every later hit (and the first requester shares these
        # arrays too) — read-only makes that a loud ValueError instead
        for leaf in jax.tree.leaves(value):
            if isinstance(leaf, np.ndarray):
                leaf.setflags(write=False)
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)
