"""Async retrieval serving: admission queue -> continuous batcher ->
pipeline -> cache -> stats.  See README.md in this package."""

from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.cache import QueryCache, quantized_key
from repro.serving.router import Router
from repro.serving.service import RetrievalService
from repro.serving.stats import (EndpointSnapshot, LatencySummary,
                                 ServiceSnapshot, ServingStats)

__all__ = [
    "ContinuousBatcher",
    "Request",
    "QueryCache",
    "quantized_key",
    "Router",
    "RetrievalService",
    "ServingStats",
    "ServiceSnapshot",
    "EndpointSnapshot",
    "LatencySummary",
]
