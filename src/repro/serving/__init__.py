"""Async retrieval serving: bounded admission queue -> continuous batcher
-> (optionally sharded) pipeline -> cache -> stats.  See README.md in this
package and docs/ARCHITECTURE.md for the full map."""

from repro.serving.autotune import (AutotuneResult, MeasuredPoint,
                                    ServingConfig, TunedProfile, autotune,
                                    check_config, measure_config,
                                    pareto_front, proxy_objectives,
                                    roofline_prune)
from repro.serving.batcher import (OVERLOAD_POLICIES, ContinuousBatcher,
                                   Request, ServiceOverloaded)
from repro.serving.cache import QueryCache, quantized_key
from repro.serving.funnel import (FUNNEL_STAGES, FunnelPipeline, StageBudget,
                                  StageTrace)
from repro.serving.live import LiveCorpus, LiveGenerator, SnapshotGenerator
from repro.serving.router import Router
from repro.serving.service import RetrievalService
from repro.serving.spec import EndpointSpec
from repro.serving.sharded import CorpusShard, ShardedPipeline, shard_corpus
from repro.serving.stats import (EndpointSnapshot, LatencySummary,
                                 ServiceSnapshot, ServingStats)

__all__ = [
    "ContinuousBatcher",
    "EndpointSpec",
    "FunnelPipeline",
    "FUNNEL_STAGES",
    "StageBudget",
    "StageTrace",
    "Request",
    "ServiceOverloaded",
    "OVERLOAD_POLICIES",
    "QueryCache",
    "quantized_key",
    "LiveCorpus",
    "LiveGenerator",
    "SnapshotGenerator",
    "Router",
    "RetrievalService",
    "CorpusShard",
    "ShardedPipeline",
    "shard_corpus",
    "ServingStats",
    "ServiceSnapshot",
    "EndpointSnapshot",
    "LatencySummary",
    "ServingConfig",
    "TunedProfile",
    "MeasuredPoint",
    "AutotuneResult",
    "autotune",
    "check_config",
    "measure_config",
    "pareto_front",
    "proxy_objectives",
    "roofline_prune",
]
