"""Admission queue + continuous batcher, with overload admission control.

One :class:`ContinuousBatcher` per endpoint owns an admission queue and a
worker thread.  The worker closes a batch on whichever knob trips first:

  * **size** — ``batch_size`` requests are waiting (throughput knob);
  * **deadline** — ``max_wait_s`` elapsed since the batch opened
    (latency knob);
  * **drain** — the service is shutting down and flushes what's queued.

Partial batches are padded to the fixed ``batch_size`` with the
endpoint's pad query (jit shape stability — the padded rows are scored
and discarded), run through the endpoint's batched runner, and the rows
fan back out to per-request futures.  A runner failure fails every
future in the batch; the worker survives and keeps serving.

Admission control: ``max_queue`` bounds the per-endpoint queue depth.
At the limit the configured ``overload`` policy decides what gives:

  * ``"block"`` (default) — the submitting thread waits for space:
    backpressure propagates to the caller, nothing is lost;
  * ``"reject"`` — ``submit`` raises :class:`ServiceOverloaded`
    immediately: the caller sees the overload synchronously and can back
    off or hedge to another replica;
  * ``"shed_oldest"`` — the oldest *queued* request is evicted (its
    future fails with :class:`ServiceOverloaded`) and the new one is
    admitted: freshest-first under overload, bounding queue wait.

Rejected/shed totals are surfaced per endpoint through
``ServingStats.snapshot()`` alongside the live queue depth and its limit.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.stats import ServingStats

__all__ = ["Request", "ContinuousBatcher", "ServiceOverloaded",
           "OVERLOAD_POLICIES"]

_POLL_S = 0.02   # stop-flag poll while the queue is idle

OVERLOAD_POLICIES = ("block", "reject", "shed_oldest")


class ServiceOverloaded(RuntimeError):
    """An admission queue is at its depth limit: raised by ``submit`` under
    policy ``"reject"``, set on the evicted request's future under
    ``"shed_oldest"``."""


@dataclasses.dataclass
class Request:
    """One in-flight query: representation + (optional) raw tokens for the
    re-ranking stages, the future the result lands in, and timestamps."""

    query_repr: Any
    q_tokens: Optional[Any]
    endpoint: str
    future: Future
    t_admit: float
    cache_key: Optional[bytes] = None
    # live-corpus generation the cache_key was stamped with at submit
    # time (None on frozen endpoints): if the batch ends up served from
    # a newer snapshot, the service re-keys the stored result to the
    # generation that actually produced it
    generation: Optional[int] = None


class _AdmissionQueue:
    """Bounded FIFO where admission, overload policy, and close are one
    atomic decision under one lock (a plain ``queue.Queue`` can't shed its
    oldest entry or refuse puts after close without racing the worker)."""

    def __init__(self, name: str, max_depth: Optional[int] = None,
                 policy: str = "block"):
        if policy not in OVERLOAD_POLICIES:
            raise ValueError(
                f"overload policy {policy!r} not in {OVERLOAD_POLICIES}")
        if max_depth is not None and max_depth < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        self._name = name
        self._max = max_depth
        self._policy = policy
        self._items: "collections.deque[Request]" = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False

    def qsize(self) -> int:
        return len(self._items)       # len() is atomic on deque

    def put(self, item: Request) -> Optional[Request]:
        """Admit ``item``; returns the evicted request under shed_oldest
        (else None).  Raises :class:`ServiceOverloaded` (reject at depth)
        or RuntimeError (closed — also wakes blocked putters)."""
        with self._lock:
            while True:
                if self._closed:
                    raise RuntimeError(f"batcher {self._name!r} is closed")
                if self._max is None or len(self._items) < self._max:
                    self._items.append(item)
                    self._not_empty.notify()
                    return None
                if self._policy == "reject":
                    raise ServiceOverloaded(
                        f"endpoint {self._name!r}: admission queue at depth "
                        f"limit {self._max}")
                if self._policy == "shed_oldest":
                    shed = self._items.popleft()
                    self._items.append(item)
                    self._not_empty.notify()
                    return shed
                # block: wait for the worker to make space (bounded wait so
                # a missed notify can never wedge the submitter)
                self._not_full.wait(timeout=_POLL_S)

    def get(self, timeout: float) -> Optional[Request]:
        deadline = time.monotonic() + timeout
        with self._lock:
            while not self._items:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._not_empty.wait(timeout=remaining)
            item = self._items.popleft()
            self._not_full.notify()
            return item

    def drain(self) -> List[Request]:
        with self._lock:
            items = list(self._items)
            self._items.clear()
            self._not_full.notify_all()
            return items

    def close(self):
        with self._lock:
            self._closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()


class ContinuousBatcher:
    def __init__(
        self,
        name: str,
        run_fn: Callable[[Any, Optional[Any]], Any],
        pad_query_repr: Any,
        pad_q_tokens: Optional[Any] = None,
        *,
        batch_size: int = 16,
        max_wait_s: float = 0.01,
        max_queue: Optional[int] = None,
        overload: str = "block",
        backend: Optional[str] = None,
        corpus_dtype: Optional[str] = None,
        profile: Optional[str] = None,
        stats: Optional[ServingStats] = None,
        on_result: Optional[Callable[[Request, Any], None]] = None,
        time_fn: Callable[[], float] = time.monotonic,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.name = name
        self.run_fn = run_fn
        self.pad_query_repr = pad_query_repr
        self.pad_q_tokens = pad_q_tokens
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.max_queue = max_queue
        self.overload = overload
        # execution-backend identity and corpus residency dtype of the
        # endpoint's runner: surfaced in stats snapshots and folded into
        # this endpoint's cache keys (two endpoints over one corpus that
        # differ only in dtype are different precision tiers and must
        # never alias)
        self.backend = backend
        self.corpus_dtype = corpus_dtype
        # tuned-profile tag (TunedProfile.tag) when this endpoint's knobs
        # came from an autotuned profile: provenance in snapshots + keys
        self.profile = profile
        self.stats = stats if stats is not None else ServingStats()
        self.on_result = on_result
        self._time_fn = time_fn
        self._queue = _AdmissionQueue(name, max_queue, overload)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name=f"batcher-{name}", daemon=True)
        self.stats.register_endpoint(name, self._queue.qsize,
                                     depth_limit=max_queue, backend=backend,
                                     corpus_dtype=corpus_dtype,
                                     profile=profile)
        self._thread.start()

    # -- client side --------------------------------------------------------
    def submit(self, request: Request):
        if self.pad_q_tokens is None and request.q_tokens is not None:
            raise ValueError(
                f"endpoint {self.name!r} was registered without "
                "pad_q_tokens, so per-request q_tokens would be silently "
                "dropped; register the endpoint with a pad_q_tokens value")
        try:
            shed = self._queue.put(request)
        except ServiceOverloaded:
            self.stats.record_overload(self.name, "rejected")
            raise
        if shed is not None:
            self.stats.record_overload(self.name, "shed")
            if shed.future.set_running_or_notify_cancel():
                shed.future.set_exception(ServiceOverloaded(
                    f"endpoint {self.name!r}: request shed from a full "
                    f"admission queue (depth limit {self.max_queue})"))

    def queue_depth(self) -> int:
        return self._queue.qsize()

    # -- worker side --------------------------------------------------------
    def _loop(self):
        while not self._stop.is_set():
            batch, closed_by = self._gather()
            if batch:
                self._safe_execute(batch, closed_by)
        # drain: everything still queued is flushed in fixed-size batches
        leftover = self._queue.drain()
        for i in range(0, len(leftover), self.batch_size):
            self._safe_execute(leftover[i:i + self.batch_size], "drain")

    def _safe_execute(self, batch: List[Request], closed_by: str):
        """The worker must survive anything a batch throws at it."""
        try:
            self._execute(batch, closed_by)
        except Exception as exc:            # noqa: BLE001
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(exc)

    def _gather(self):
        """Block for the first request, then fill until size or deadline."""
        first = self._queue.get(timeout=_POLL_S)
        if first is None:
            return [], None
        batch = [first]
        deadline = self._time_fn() + self.max_wait_s
        while len(batch) < self.batch_size:
            if self._stop.is_set():
                return batch, "drain"
            remaining = deadline - self._time_fn()
            if remaining <= 0:
                return batch, "deadline"
            nxt = self._queue.get(timeout=min(remaining, _POLL_S))
            if nxt is None:
                continue   # re-check stop flag and deadline
            batch.append(nxt)
        return batch, "size"

    def _assemble(self, batch: List[Request]):
        n_pad = self.batch_size - len(batch)
        reprs = [r.query_repr for r in batch] + [self.pad_query_repr] * n_pad
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reprs)
        if self.pad_q_tokens is None:
            return stacked, None
        toks = [r.q_tokens for r in batch] + [self.pad_q_tokens] * n_pad
        return stacked, jax.tree.map(lambda *xs: jnp.stack(xs), *toks)

    def _execute(self, batch: List[Request], closed_by: str):
        t0 = self._time_fn()
        try:
            stacked, tokens = self._assemble(batch)
            if getattr(self.run_fn, "budget_aware", False):
                # budget-aware runners (the served funnel) get the time
                # this batch already spent queued — enforcement starts
                # at batch close, so an end-to-end budget covers the
                # request's whole life, not just compute
                elapsed = max(t0 - min(r.t_admit for r in batch), 0.0)
                out = self.run_fn(stacked, tokens, elapsed_s=elapsed)
            else:
                out = self.run_fn(stacked, tokens)
            out = jax.tree.map(
                lambda x: np.asarray(jax.block_until_ready(x)), out)
        except Exception as exc:            # noqa: BLE001 — fan out to futures
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(exc)
            return
        t1 = self._time_fn()
        self.stats.record_batch(
            self.name, served=len(batch), capacity=self.batch_size,
            closed_by=closed_by,
            queue_waits_s=[t0 - r.t_admit for r in batch],
            exec_s=t1 - t0)
        for i, r in enumerate(batch):
            result = jax.tree.map(lambda x: x[i], out)
            if self.on_result is not None:
                self.on_result(r, result)
            self.stats.record_e2e(self.name, self._time_fn() - r.t_admit)
            # a client may have cancelled the future while it was queued;
            # claiming it as running makes set_result race-free
            if r.future.set_running_or_notify_cancel():
                r.future.set_result(result)

    def close(self):
        """Stop accepting (wakes blocked submitters), flush the queue, join
        the worker.  Requests admitted before close are still served."""
        self._queue.close()
        self._stop.set()
        self._thread.join()
