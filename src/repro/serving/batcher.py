"""Admission queue + continuous batcher.

One :class:`ContinuousBatcher` per endpoint owns an admission queue and a
worker thread.  The worker closes a batch on whichever knob trips first:

  * **size** — ``batch_size`` requests are waiting (throughput knob);
  * **deadline** — ``max_wait_s`` elapsed since the batch opened
    (latency knob);
  * **drain** — the service is shutting down and flushes what's queued.

Partial batches are padded to the fixed ``batch_size`` with the
endpoint's pad query (jit shape stability — the padded rows are scored
and discarded), run through the endpoint's batched runner, and the rows
fan back out to per-request futures.  A runner failure fails every
future in the batch; the worker survives and keeps serving.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.stats import ServingStats

__all__ = ["Request", "ContinuousBatcher"]

_POLL_S = 0.02   # stop-flag poll while the queue is idle


@dataclasses.dataclass
class Request:
    """One in-flight query: representation + (optional) raw tokens for the
    re-ranking stages, the future the result lands in, and timestamps."""

    query_repr: Any
    q_tokens: Optional[Any]
    endpoint: str
    future: Future
    t_admit: float
    cache_key: Optional[bytes] = None


class ContinuousBatcher:
    def __init__(
        self,
        name: str,
        run_fn: Callable[[Any, Optional[Any]], Any],
        pad_query_repr: Any,
        pad_q_tokens: Optional[Any] = None,
        *,
        batch_size: int = 16,
        max_wait_s: float = 0.01,
        stats: Optional[ServingStats] = None,
        on_result: Optional[Callable[[Request, Any], None]] = None,
        time_fn: Callable[[], float] = time.monotonic,
    ):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.name = name
        self.run_fn = run_fn
        self.pad_query_repr = pad_query_repr
        self.pad_q_tokens = pad_q_tokens
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.stats = stats if stats is not None else ServingStats()
        self.on_result = on_result
        self._time_fn = time_fn
        self._queue: "queue_mod.Queue[Request]" = queue_mod.Queue()
        self._stop = threading.Event()
        # couples the stop check to the enqueue: without it a submit racing
        # close() could enqueue after the drain pass and hang its future
        self._submit_lock = threading.Lock()
        self._thread = threading.Thread(
            target=self._loop, name=f"batcher-{name}", daemon=True)
        self.stats.register_endpoint(name, self._queue.qsize)
        self._thread.start()

    # -- client side --------------------------------------------------------
    def submit(self, request: Request):
        if self.pad_q_tokens is None and request.q_tokens is not None:
            raise ValueError(
                f"endpoint {self.name!r} was registered without "
                "pad_q_tokens, so per-request q_tokens would be silently "
                "dropped; register the endpoint with a pad_q_tokens value")
        with self._submit_lock:
            if self._stop.is_set():
                raise RuntimeError(f"batcher {self.name!r} is closed")
            self._queue.put(request)

    def queue_depth(self) -> int:
        return self._queue.qsize()

    # -- worker side --------------------------------------------------------
    def _loop(self):
        while not self._stop.is_set():
            batch, closed_by = self._gather()
            if batch:
                self._safe_execute(batch, closed_by)
        # drain: everything still queued is flushed in fixed-size batches
        leftover: List[Request] = []
        while True:
            try:
                leftover.append(self._queue.get_nowait())
            except queue_mod.Empty:
                break
        for i in range(0, len(leftover), self.batch_size):
            self._safe_execute(leftover[i:i + self.batch_size], "drain")

    def _safe_execute(self, batch: List[Request], closed_by: str):
        """The worker must survive anything a batch throws at it."""
        try:
            self._execute(batch, closed_by)
        except Exception as exc:            # noqa: BLE001
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(exc)

    def _gather(self):
        """Block for the first request, then fill until size or deadline."""
        try:
            first = self._queue.get(timeout=_POLL_S)
        except queue_mod.Empty:
            return [], None
        batch = [first]
        deadline = self._time_fn() + self.max_wait_s
        while len(batch) < self.batch_size:
            if self._stop.is_set():
                return batch, "drain"
            remaining = deadline - self._time_fn()
            if remaining <= 0:
                return batch, "deadline"
            try:
                batch.append(
                    self._queue.get(timeout=min(remaining, _POLL_S)))
            except queue_mod.Empty:
                continue   # re-check stop flag and deadline
        return batch, "size"

    def _assemble(self, batch: List[Request]):
        n_pad = self.batch_size - len(batch)
        reprs = [r.query_repr for r in batch] + [self.pad_query_repr] * n_pad
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reprs)
        if self.pad_q_tokens is None:
            return stacked, None
        toks = [r.q_tokens for r in batch] + [self.pad_q_tokens] * n_pad
        return stacked, jax.tree.map(lambda *xs: jnp.stack(xs), *toks)

    def _execute(self, batch: List[Request], closed_by: str):
        t0 = self._time_fn()
        try:
            stacked, tokens = self._assemble(batch)
            out = self.run_fn(stacked, tokens)
            out = jax.tree.map(
                lambda x: np.asarray(jax.block_until_ready(x)), out)
        except Exception as exc:            # noqa: BLE001 — fan out to futures
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(exc)
            return
        t1 = self._time_fn()
        self.stats.record_batch(
            self.name, served=len(batch), capacity=self.batch_size,
            closed_by=closed_by,
            queue_waits_s=[t0 - r.t_admit for r in batch],
            exec_s=t1 - t0)
        for i, r in enumerate(batch):
            result = jax.tree.map(lambda x: x[i], out)
            if self.on_result is not None:
                self.on_result(r, result)
            self.stats.record_e2e(self.name, self._time_fn() - r.t_admit)
            # a client may have cancelled the future while it was queued;
            # claiming it as running makes set_result race-free
            if r.future.set_running_or_notify_cancel():
                r.future.set_result(result)

    def close(self):
        """Stop accepting, flush the queue, join the worker."""
        with self._submit_lock:
            self._stop.set()
        self._thread.join()
