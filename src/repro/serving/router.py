"""Endpoint router: request -> the right pipeline's batcher.

The paper's three spaces (dense, sparse, fused) become live endpoints of
one service; each endpoint owns a :class:`ContinuousBatcher` with its own
batch-size / deadline / admission-control knobs, so a cheap sparse lookup
and an expensive fused funnel never share a batch (or a queue limit).

A sharded corpus is invisible here: a ``ShardedPipeline`` registers as
one ordinary endpoint, so routing, caching, and stats never learn how
many shards sit behind it.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.serving.batcher import ContinuousBatcher, Request

__all__ = ["Router"]


class Router:
    def __init__(self):
        self._batchers: Dict[str, ContinuousBatcher] = {}

    def register(self, batcher: ContinuousBatcher):
        if batcher.name in self._batchers:
            raise ValueError(f"endpoint {batcher.name!r} already registered")
        self._batchers[batcher.name] = batcher

    def endpoints(self):
        return tuple(self._batchers)

    def resolve(self, endpoint: Optional[str]) -> ContinuousBatcher:
        """``None`` resolves to the sole endpoint when only one exists."""
        if endpoint is None:
            if len(self._batchers) == 1:
                return next(iter(self._batchers.values()))
            raise ValueError(
                f"endpoint required: service has {sorted(self._batchers)}")
        try:
            return self._batchers[endpoint]
        except KeyError:
            raise KeyError(
                f"unknown endpoint {endpoint!r}; "
                f"registered: {sorted(self._batchers)}") from None

    def dispatch(self, request: Request):
        self.resolve(request.endpoint).submit(request)

    def close(self):
        for b in self._batchers.values():
            b.close()
