"""RetrievalService — the async serving facade.

Wires the pieces together (the full data-flow map, including sharding and
admission control, lives in ``docs/ARCHITECTURE.md``)::

    submit() --cache hit--> future (already resolved)
        \\--miss--> Router --> per-endpoint ContinuousBatcher
                                   |  bounded admission queue
                                   |  (overflow: block | reject | shed)
                                   |  size/deadline close, pad, stack
                                   v
                          batched runner (RetrievalPipeline.run /
                                          ShardedPipeline.run / jit fn)
                                   |  slice rows, fill cache, record stats
                                   v
                            per-request Future

Endpoints register either a :class:`~repro.core.pipeline.RetrievalPipeline`
(optionally jitted), a :class:`~repro.serving.sharded.ShardedPipeline`
(K corpus shards behind this one endpoint), or any batched runner
``fn(query_repr, q_tokens) -> pytree``.  Results delivered through futures
are numpy pytrees (one row of the batched output), bit-identical to an
offline ``pipeline.run`` on the same queries — verified in
``tests/test_serving.py`` and ``tests/test_sharded.py``.

Execution backends are per endpoint: ``register_pipeline(...,
backend=...)`` rebinds the pipeline's candidate stage onto the named
:mod:`repro.core.backends` path (reference / streaming / pallas / auto),
so the same corpus can be live behind several endpoints that differ only
in how they execute — the backend identity shows up in stats snapshots
and is part of the endpoint's cache keys.  Corpus residency dtype is per
endpoint the same way: ``register_pipeline(..., corpus_dtype=
"bfloat16")`` serves the funnel from a half-footprint bf16 corpus
(scores stay f32 — the precision contract in ``core.spaces``), with the
dtype surfaced in snapshots and keyed into the cache so precision tiers
never alias.

Admission control is per endpoint: ``max_queue`` bounds the endpoint's
queue depth, ``overload`` picks the at-limit policy (``"block"`` —
backpressure the submitter, ``"reject"`` — raise
:class:`~repro.serving.batcher.ServiceOverloaded`, ``"shed_oldest"`` —
evict the stalest queued request).  Cache hits bypass the queue entirely
and are served even when the endpoint is saturated.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Any, Callable, Iterable, List, Optional

import jax

from repro.core.backends import backend_identity
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.cache import QueryCache
from repro.serving.router import Router
from repro.serving.spec import EndpointSpec
from repro.serving.stats import ServiceSnapshot, ServingStats

__all__ = ["RetrievalService"]

# defaults of the legacy keyword registration surface: used to detect a
# kwarg passed alongside spec= (ambiguous — the spec carries every knob)
_KWARG_DEFAULTS = dict(batch_size=16, max_wait_s=0.01, jit=False,
                       max_queue=None, overload="block", backend=None,
                       corpus_dtype=None, profile=None, live=None,
                       budget=None, rerank_keep=None)


def _no_kwargs_alongside_spec(**kwargs):
    clashes = sorted(k for k, v in kwargs.items() if v != _KWARG_DEFAULTS[k])
    if clashes:
        raise ValueError(
            f"spec= carries every registration knob; also passing "
            f"{', '.join(clashes)} is ambiguous — set them on the "
            f"EndpointSpec (dataclasses.replace) instead")


def _pipeline_backend_label(pipeline) -> Optional[str]:
    """Execution-backend identity of a pipeline's generator stage (None
    when the pipeline has no backend seam — e.g. graph-ANN generators)."""
    label = backend_identity(getattr(pipeline, "backend", None))
    if label is not None:
        return label
    gens = getattr(pipeline, "generators", None)    # ShardedPipeline
    if gens is None:                                # funnel over sharded
        gens = getattr(getattr(pipeline, "generator", None),
                       "generators", None)
    if gens:
        ids = sorted({lbl for g in gens
                      if (lbl := backend_identity(getattr(g, "backend",
                                                          None))) is not None})
        if len(ids) == 1:
            return ids[0]
        if ids:
            return "mixed(" + ",".join(ids) + ")"
    return None


def _pipeline_corpus_dtype(pipeline) -> Optional[str]:
    """Corpus residency dtype behind a pipeline's generator stage (None
    when there is no dtype seam or per-shard generators disagree).

    A pipeline exposing ``corpus_dtype`` is trusted as-is — including a
    None that means "my shards disagree" (``ShardedPipeline`` already
    aggregates honestly).  The per-generator fallback, for duck-typed
    sharded pipelines, treats a seamless generator (dtype None) next to
    a typed one as *unknown*, never as the typed tier: claiming a
    uniform precision tier the endpoint doesn't have would poison stats
    attribution and cache keying."""
    if hasattr(pipeline, "corpus_dtype"):
        return pipeline.corpus_dtype
    gens = getattr(pipeline, "generators", None)    # duck-typed sharded
    if gens:
        dts = {getattr(g, "corpus_dtype", None) for g in gens}
        if len(dts) == 1 and (d := dts.pop()) is not None:
            return d
        if None not in dts and len(dts) > 1:
            return "mixed(" + ",".join(sorted(dts)) + ")"
    return None


class RetrievalService:
    """Multi-endpoint async retrieval with continuous batching + caching.

    ``cache_size=0`` disables the result cache entirely (every request
    goes through the funnel) — the bench's cache-off baseline."""

    def __init__(self, *, cache_size: int = 4096, cache_decimals: int = 6,
                 time_fn: Callable[[], float] = time.monotonic):
        self._time_fn = time_fn
        self.stats = ServingStats(time_fn=time_fn)
        self.cache = (QueryCache(cache_size, cache_decimals)
                      if cache_size > 0 else None)
        self.router = Router()
        # pipelines this service created itself (backend rebinds at
        # registration) and therefore must close: a rebound
        # ShardedPipeline owns a fresh host-parallel pool the caller
        # never sees
        self._owned_pipelines: List[Any] = []
        # endpoint name -> (LiveCorpus, served-generation reader) for
        # endpoints registered with register_pipeline(live=...): submit
        # stamps the current generation into cache keys, _on_result
        # re-keys to the generation the batch actually served
        self._live_endpoints: dict = {}
        self._closed = False

    # -- endpoint registration ----------------------------------------------
    def register_runner(
        self, name: str, run_fn: Callable[[Any, Optional[Any]], Any],
        pad_query_repr: Any, pad_q_tokens: Optional[Any] = None, *,
        spec: Optional[EndpointSpec] = None,
        batch_size: int = 16, max_wait_s: float = 0.01, jit: bool = False,
        max_queue: Optional[int] = None, overload: str = "block",
        backend: Optional[Any] = None, corpus_dtype: Optional[str] = None,
        profile: Optional[Any] = None,
    ) -> "RetrievalService":
        """``spec`` (an :class:`~repro.serving.spec.EndpointSpec`)
        carries every registration knob as one validated value — the
        canonical surface.  The loose keywords below remain as a shim
        that builds the same spec.

        ``backend`` (a name, identity string, or ExecutionBackend
        instance) declares the execution path behind ``run_fn``;
        ``corpus_dtype`` declares its corpus residency dtype (the
        precision tier).  Both are surfaced in stats snapshots and keyed
        into this endpoint's cache entries.  For opaque runners they are
        labels only — the runner is not rewritten (use
        :meth:`register_pipeline` for that).

        ``profile`` (a :class:`~repro.serving.autotune.TunedProfile`)
        binds the endpoint's batching/admission knobs — batch size,
        deadline, queue bound, overload policy — from an autotuned
        Pareto-front row in one shot, and declares the profile's backend
        identity and corpus dtype when no explicit labels are given.
        The profile's ``tag`` is surfaced in snapshots and folded into
        this endpoint's cache keys (provenance).  Note
        ``profile.config.cache_size`` is a *service*-level knob — pass
        it to the :class:`RetrievalService` constructor."""
        if spec is not None:
            _no_kwargs_alongside_spec(
                batch_size=batch_size, max_wait_s=max_wait_s, jit=jit,
                max_queue=max_queue, overload=overload, backend=backend,
                corpus_dtype=corpus_dtype, profile=profile)
        elif profile is not None:
            # historical register_runner asymmetry, kept: explicit
            # backend/corpus_dtype *labels* override the profile's
            # (the runner is opaque — nothing is rebound either way)
            overrides: dict = {"jit": jit}
            if backend is not None:
                overrides["backend"] = backend
            if corpus_dtype is not None:
                overrides["corpus_dtype"] = corpus_dtype
            spec = dataclasses.replace(profile.to_spec(), **overrides)
        else:
            spec = EndpointSpec.from_kwargs(
                batch_size=batch_size, max_wait_s=max_wait_s, jit=jit,
                max_queue=max_queue, overload=overload, backend=backend,
                corpus_dtype=corpus_dtype)
        if spec.live is not None:
            raise ValueError(
                "live endpoints register through register_pipeline: the "
                "service must own the snapshot-pinning run path")
        if spec.jit:
            run_fn = jax.jit(run_fn)
        batcher = ContinuousBatcher(
            name, run_fn, pad_query_repr, pad_q_tokens,
            batch_size=spec.batch_size, max_wait_s=spec.max_wait_s,
            max_queue=spec.max_queue, overload=spec.overload,
            backend=backend_identity(spec.backend),
            corpus_dtype=spec.corpus_dtype,
            profile=None if spec.profile is None else spec.profile.tag,
            stats=self.stats, on_result=self._on_result,
            time_fn=self._time_fn)
        self.router.register(batcher)
        return self

    def register_pipeline(
        self, name: str, pipeline, pad_query_repr: Any,
        pad_q_tokens: Optional[Any] = None, *,
        spec: Optional[EndpointSpec] = None,
        batch_size: int = 16, max_wait_s: float = 0.01, jit: bool = False,
        max_queue: Optional[int] = None, overload: str = "block",
        backend: Optional[Any] = None, corpus_dtype: Optional[str] = None,
        profile: Optional[Any] = None, live: Optional[Any] = None,
        budget: Optional[Any] = None, rerank_keep: Optional[int] = None,
    ) -> "RetrievalService":
        """Serve a :class:`RetrievalPipeline`, a
        :class:`~repro.serving.sharded.ShardedPipeline`, or a
        :class:`~repro.serving.funnel.FunnelPipeline` (anything with a
        batched ``run(query_repr, q_tokens)``) as endpoint ``name``.

        ``spec`` (an :class:`~repro.serving.spec.EndpointSpec`) is the
        canonical registration surface: every knob below, as one frozen
        validated value.  The loose keywords remain as a shim that
        builds the same spec (same mutual-exclusion rules).

        A funnel endpoint (the pipeline has ``run_timed``) additionally
        gets per-stage treatment: each batch's candgen/fusion/rerank
        stage is timed into the endpoint snapshot's ``stages`` summary,
        ``budget`` (a :class:`~repro.serving.funnel.StageBudget`) and
        ``rerank_keep`` rebind the funnel's budgets and served width at
        registration, and the batcher hands the batch's queue wait to
        the funnel so the end-to-end budget can degrade the rerank stage
        (skip-and-serve-fused, counted as ``stage_fallbacks`` — never an
        error).  Funnel endpoints cannot be jitted: the staged run path
        times stages and makes budget decisions on the host.

        ``backend`` selects the execution path for the pipeline's
        candidate stage (``"reference"`` / ``"streaming"`` / ``"pallas"``
        / ``"auto"`` / an ExecutionBackend instance): the pipeline is
        rebound via ``with_backend`` before registration, so one corpus
        can be served as several endpoints differing only in backend.
        ``corpus_dtype`` rebinds the corpus residency dtype the same way
        (via ``with_corpus_dtype``, applied *before* backend resolution
        so capability checks see the dtype that will actually be
        scanned): ``corpus_dtype="bfloat16"`` serves the same funnel
        from a half-footprint corpus on the bounded-error precision tier.
        The resolved identity and dtype land in stats snapshots and
        cache keys.  A pipeline without the corresponding seam (no
        ``with_backend`` / ``with_corpus_dtype``) is rejected here — use
        :meth:`register_runner` for label-only declarations, so stats
        never claim a path that is not actually executing.

        ``profile`` (a :class:`~repro.serving.autotune.TunedProfile`)
        rebinds backend, corpus dtype, batching and admission control
        from an autotuned Pareto-front row in one shot — mutually
        exclusive with explicit ``backend``/``corpus_dtype`` (a profile
        IS those choices; overriding half of one silently would serve a
        point nobody measured).  The pipeline's shard count must match
        the profile's genome for the same reason.  The profile tag lands
        in snapshots and cache keys; ``profile.config.cache_size`` is a
        service-level knob (the :class:`RetrievalService` constructor).

        ``live`` (a :class:`~repro.serving.live.LiveCorpus`) serves a
        *mutable* corpus: pass ``pipeline=None`` to serve the live
        corpus's candidate stage directly, or a
        :class:`~repro.core.pipeline.RetrievalPipeline` whose generator
        is a ``LiveGenerator`` over the same corpus for custom funnel
        depths.  Mutually exclusive with ``backend`` / ``corpus_dtype``
        / ``profile`` / ``jit`` — the live corpus declares its own
        backends and dtype, and its run path is snapshot-pinning host
        code.  Every batch is pinned to one snapshot; the snapshot
        generation is length-framed into this endpoint's cache keys
        (stored under the generation that produced the result), so a
        mutation or compaction can never surface a stale hit.  Endpoint
        snapshots gain segment row counts, tombstones, compaction
        latency, and snapshot age."""
        if spec is not None:
            _no_kwargs_alongside_spec(
                batch_size=batch_size, max_wait_s=max_wait_s, jit=jit,
                max_queue=max_queue, overload=overload, backend=backend,
                corpus_dtype=corpus_dtype, profile=profile, live=live,
                budget=budget, rerank_keep=rerank_keep)
        else:
            spec = EndpointSpec.from_kwargs(
                batch_size=batch_size, max_wait_s=max_wait_s, jit=jit,
                max_queue=max_queue, overload=overload, backend=backend,
                corpus_dtype=corpus_dtype, profile=profile, live=live,
                budget=budget, rerank_keep=rerank_keep)
        if spec.live is not None:
            from repro.core.pipeline import RetrievalPipeline
            from repro.serving.live import LiveGenerator

            live = spec.live
            if pipeline is None:
                pipeline = RetrievalPipeline(generator=LiveGenerator(live))
            generator = getattr(pipeline, "generator", None)
            if not isinstance(generator, LiveGenerator) \
                    or generator.live is not live:
                raise ValueError(
                    "live= requires pipeline=None or a RetrievalPipeline "
                    "/ FunnelPipeline whose generator is a LiveGenerator "
                    "over the same LiveCorpus")
            pipeline, is_funnel = self._bind_funnel_knobs(pipeline, spec)
            run_fn = (self._funnel_run_fn(name, pipeline) if is_funnel
                      else pipeline.run)
            self.register_runner(
                name, run_fn, pad_query_repr, pad_q_tokens,
                spec=dataclasses.replace(
                    spec, live=None,
                    backend=backend_identity(live.main_backend),
                    corpus_dtype=live.corpus_dtype))
            self.stats.register_endpoint(name, live_fn=live.live_stats)
            self._live_endpoints[name] = (
                live, lambda: generator.last_served_generation)
            return self
        if spec.profile is not None:
            n_shards = getattr(pipeline, "n_shards", 1)
            if n_shards != spec.profile.config.n_shards:
                raise ValueError(
                    f"profile was tuned for n_shards="
                    f"{spec.profile.config.n_shards} but the pipeline has "
                    f"{n_shards} shard(s)")
        pipeline, is_funnel = self._bind_funnel_knobs(pipeline, spec)
        original = pipeline
        if spec.corpus_dtype is not None:
            if not hasattr(pipeline, "with_corpus_dtype"):
                raise TypeError(
                    f"pipeline {type(pipeline).__name__} does not take a "
                    "corpus residency dtype (no with_corpus_dtype); "
                    "register it via register_runner(corpus_dtype=...) if "
                    "you only want the label in stats/cache keys")
            pipeline = pipeline.with_corpus_dtype(spec.corpus_dtype)
        if spec.backend is not None:
            if not hasattr(pipeline, "with_backend"):
                raise TypeError(
                    f"pipeline {type(pipeline).__name__} does not take an "
                    "execution backend (no with_backend); register it via "
                    "register_runner(backend=...) if you only want the "
                    "label in stats/cache keys")
            intermediate = pipeline
            pipeline = pipeline.with_backend(spec.backend)
            # a dtype rebind of a sharded pipeline owns a worker pool the
            # backend rebind replaced: retire the intermediate now
            if intermediate is not original and hasattr(intermediate,
                                                        "close"):
                intermediate.close()
        if pipeline is not original and hasattr(pipeline, "close"):
            self._owned_pipelines.append(pipeline)
        label = _pipeline_backend_label(pipeline)
        if label is None:
            label = backend_identity(spec.backend)
        dtype_label = _pipeline_corpus_dtype(pipeline)
        if dtype_label is None:
            dtype_label = spec.corpus_dtype

        if is_funnel:
            run_fn = self._funnel_run_fn(name, pipeline)
        else:
            def run_fn(query_repr, q_tokens):
                return pipeline.run(query_repr, q_tokens)
        return self.register_runner(
            name, run_fn, pad_query_repr, pad_q_tokens,
            spec=dataclasses.replace(spec, backend=label,
                                     corpus_dtype=dtype_label))

    @staticmethod
    def _bind_funnel_knobs(pipeline, spec: EndpointSpec):
        """Apply the spec's funnel knobs (``rerank_keep`` width, stage
        ``budget``) to a :class:`~repro.serving.funnel.FunnelPipeline`;
        returns ``(pipeline, is_funnel)``.  Non-funnel pipelines reject
        funnel knobs so a budget can never be silently inert."""
        is_funnel = hasattr(pipeline, "run_timed")
        if not is_funnel:
            if spec.budget is not None or spec.rerank_keep is not None:
                raise ValueError(
                    "budget= / rerank_keep= are funnel knobs: they apply "
                    "to FunnelPipeline endpoints (this pipeline has no "
                    "run_timed stage seam)")
            return pipeline, False
        if spec.jit:
            raise ValueError(
                "funnel endpoints cannot be jitted: the staged run path "
                "times stages and makes budget decisions on the host")
        if spec.rerank_keep is not None:
            pipeline = pipeline.with_rerank_keep(spec.rerank_keep)
        if spec.budget is not None:
            pipeline = pipeline.with_budget(spec.budget)
        return pipeline, True

    def _funnel_run_fn(self, name: str, funnel):
        """The batched runner for a funnel endpoint: runs the staged
        funnel and records per-stage seconds / fallbacks / overruns into
        this service's stats.  Marked ``budget_aware`` so the batcher
        hands over the batch's queue wait (``elapsed_s``) — budget
        enforcement starts at batch close, not at stage one."""
        stats = self.stats

        def run_fn(query_repr, q_tokens, *, elapsed_s: float = 0.0):
            out, trace = funnel.run_timed(query_repr, q_tokens,
                                          elapsed_s=elapsed_s)
            stats.record_stage(name, "candgen", trace.candgen_s,
                               overrun="candgen" in trace.overruns)
            if trace.fusion_s is not None:
                stats.record_stage(name, "fusion", trace.fusion_s,
                                   overrun="fusion" in trace.overruns)
            if trace.rerank_s is not None:
                stats.record_stage(name, "rerank", trace.rerank_s,
                                   overrun="rerank" in trace.overruns)
            elif trace.fallback:
                stats.record_stage(name, "rerank", None, fallback=True)
            return out

        run_fn.budget_aware = True
        return run_fn

    def endpoints(self):
        return self.router.endpoints()

    # -- request path --------------------------------------------------------
    def submit(self, query_repr: Any, q_tokens: Optional[Any] = None,
               endpoint: Optional[str] = None) -> Future:
        """Admit one query; returns a Future of its per-query result.

        On an endpoint with ``overload="reject"`` at its depth limit this
        raises :class:`~repro.serving.batcher.ServiceOverloaded`
        synchronously (the rejection is counted in the endpoint's stats);
        with ``"shed_oldest"`` the evicted request's future fails with the
        same exception instead.  ``n_requests`` counts every admission
        attempt, served or rejected."""
        if self._closed:
            raise RuntimeError("service is closed")
        batcher = self.router.resolve(endpoint)
        t_admit = self._time_fn()
        self.stats.record_request(batcher.name)
        key = None
        live_entry = self._live_endpoints.get(batcher.name)
        generation = (live_entry[0].generation
                      if live_entry is not None else None)
        if self.cache is not None:
            key = self.cache.key(batcher.name, (query_repr, q_tokens),
                                 backend=batcher.backend,
                                 corpus_dtype=batcher.corpus_dtype,
                                 profile=batcher.profile,
                                 generation=generation)
            hit = self.cache.get(key)
            if hit is not None:
                self.stats.record_cache(True)
                fut: Future = Future()
                self.stats.record_e2e(batcher.name,
                                      self._time_fn() - t_admit)
                fut.set_result(hit)
                return fut
        fut = Future()
        self.router.dispatch(Request(
            query_repr=query_repr, q_tokens=q_tokens, endpoint=batcher.name,
            future=fut, t_admit=t_admit, cache_key=key,
            generation=generation))
        # counted only after dispatch succeeds: a rejected submit is not a
        # cache miss, so hit-rate keeps meaning "share of admitted requests
        # answered from cache" even under overload
        if self.cache is not None:
            self.stats.record_cache(False)
        return fut

    def submit_many(self, queries: Iterable[Any],
                    q_tokens: Optional[Iterable[Any]] = None,
                    endpoint: Optional[str] = None) -> List[Future]:
        qs = list(queries)
        ts = list(q_tokens) if q_tokens is not None else [None] * len(qs)
        return [self.submit(q, t, endpoint) for q, t in zip(qs, ts)]

    def retrieve(self, queries: Iterable[Any],
                 q_tokens: Optional[Iterable[Any]] = None,
                 endpoint: Optional[str] = None) -> List[Any]:
        """Blocking convenience: submit everything, wait, return results."""
        return [f.result() for f in
                self.submit_many(queries, q_tokens, endpoint)]

    def _on_result(self, request: Request, result: Any):
        if self.cache is not None and request.cache_key is not None:
            key = request.cache_key
            entry = self._live_endpoints.get(request.endpoint)
            if entry is not None:
                # Store under the generation that actually produced the
                # result: the batch may have closed after a mutation
                # landed between submit and execution.  The pinned
                # generation is read from the generator on this same
                # batcher worker thread, right after the batch ran, so
                # it cannot race a later batch.  Lookups always key the
                # *current* generation, so a hit is by construction a
                # result computed at the generation it claims.
                live, served_generation = entry
                served = served_generation()
                if served is not None and served != request.generation:
                    batcher = self.router.resolve(request.endpoint)
                    key = self.cache.key(
                        request.endpoint,
                        (request.query_repr, request.q_tokens),
                        backend=batcher.backend,
                        corpus_dtype=batcher.corpus_dtype,
                        profile=batcher.profile, generation=served)
            self.cache.put(key, result)

    # -- lifecycle / observability -------------------------------------------
    def snapshot(self) -> ServiceSnapshot:
        return self.stats.snapshot()

    def reset_stats(self):
        """Zero counters after warm-up so snapshots cover only real load."""
        self.stats.reset()

    def close(self):
        if not self._closed:
            self._closed = True
            self.router.close()
            # batcher workers are joined by now, so no in-flight batch
            # can still be using these
            for pipeline in self._owned_pipelines:
                pipeline.close()

    def __enter__(self) -> "RetrievalService":
        return self

    def __exit__(self, *exc):
        self.close()
