"""repro — Flexible Retrieval with NMSLIB + FlexNeuART as a multi-pod
JAX/TPU framework.  See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
